"""Fig 3: impact of workload colocation on throughput and scheduling overhead.

(a) throughput@1s vs density; (b) overhead % of CPU; (c) mean switch cost.
``--cluster-mode`` reproduces §3.2 (Knative node: depth-5 hierarchy, 100
pods, longer bursts -> ~20 % overhead at ~48 us/switch).

Runs with telemetry on: derived columns include schedstat-backed tail stats
(p99 per-switch cost, peak run-queue depth), and ``--obs-dir DIR`` records
one diffable run record per configuration for ``repro.obs.report``.
"""
from __future__ import annotations

import os
import sys
import time

import repro.obs as obs
from benchmarks.common import DUR, N_CORES, emit, run_sim


def _rec(obs_dir: str, name: str):
    return os.path.join(obs_dir, name) if obs_dir else None


def main(cluster_mode: bool = False, densities=(3, 9, 13, 19),
         obs_dir: str = "") -> list:
    obs.enable()
    rows = []
    if cluster_mode:
        t0 = time.time()
        r = run_sim("azure2021", 100, "cfs", depth=5.0, burst_us=280.0,
                    exec_s=0.2, record_dir=_rec(obs_dir, "cluster_cfs"))
        s = r.sched_summary()
        rows.append((
            "fig3.cluster_mode.cfs",
            (time.time() - t0) * 1e6,
            f"ovh={r.overhead_frac*100:.1f}%;switch_us={r.mean_switch_cost_us:.1f};"
            f"p99sw_us={s.switch_cost_us.pct(99):.1f}",
        ))
        return rows
    for kind in ("azure2021", "resctl"):
        for d in densities:
            t0 = time.time()
            r = run_sim(kind, d * N_CORES, "cfs",
                        record_dir=_rec(obs_dir, f"{kind}_d{d}"))
            s = r.sched_summary()
            rows.append((
                f"fig3.{kind}.d{d}",
                (time.time() - t0) * 1e6,
                (
                    f"thr_slo={r.throughput_slo():.1f}rps;"
                    f"ovh={r.overhead_frac*100:.1f}%;"
                    f"switch_us={r.mean_switch_cost_us:.1f};"
                    f"sw_per_s={r.switches/DUR:.0f};"
                    f"p99sw_us={s.switch_cost_us.pct(99):.1f};"
                    f"runq_peak={s.runq_peak():.0f}"
                ),
            ))
    return rows


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = ""
    if "--obs-dir" in argv:
        out = argv[argv.index("--obs-dir") + 1]
    emit(main(cluster_mode="--cluster-mode" in argv, obs_dir=out))
