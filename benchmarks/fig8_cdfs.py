"""Fig 8: latency CDFs at low (3x) / high (11x) / overload (19x) colocation
for azure2021 / resctl / random, CFS vs CFS-LAGS."""
from __future__ import annotations

import time

from benchmarks.common import N_CORES, emit, run_sim

DENSITIES = (3, 11, 19)
KINDS = ("azure2021", "resctl", "random")


def main() -> list:
    rows = []
    for kind in KINDS:
        for d in DENSITIES:
            for pol in ("cfs", "lags"):
                t0 = time.time()
                r = run_sim(kind, d * N_CORES, pol)
                rows.append((
                    f"fig8.{kind}.d{d}.{pol}",
                    (time.time() - t0) * 1e6,
                    f"p50={r.pct(50):.3f};p95={r.pct(95):.3f};"
                    f"p99={r.pct(99):.3f}",
                ))
    return rows


if __name__ == "__main__":
    emit(main())
