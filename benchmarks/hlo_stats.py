"""Roofline terms per dry-run cell (re-exports the canonical HLO parser
from repro.launch.hlo_analysis)."""
from __future__ import annotations

from repro.launch.hlo_analysis import (  # noqa: F401
    CHIP,
    Chip,
    COLLECTIVE_FACTOR,
    DTYPE_BYTES,
    collective_stats_attributed,
    parse_computations,
)


def roofline_terms(cell: dict) -> dict:
    """memory_s and collective_s for one dry-run report cell."""
    coll = cell.get("collectives", {})
    wire = float(coll.get("total_bytes", 0.0))
    collective_s = wire / (CHIP.link_bw * CHIP.n_links)
    from benchmarks.flops_model import memory_bytes

    mem = memory_bytes(cell["arch"], cell["shape"],
                       n_dev=512 if cell["mesh"] == "2x16x16" else 256)
    memory_s = mem / CHIP.hbm_bw
    return {"memory_s": memory_s, "collective_s": collective_s,
            "wire_bytes": wire, "hbm_bytes": mem}
