"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro.core.policies import make_policy
from repro.core.simkernel import SimConfig, simulate
from repro.core.traces import make_workload

N_CORES = 12
DUR = 30.0  # seconds of simulated time per run (fast mode)


def run_sim(kind: str, n_fns: int, policy: str, *, duration=DUR, seed=1,
            depth=2.0, burst_us=120.0, window=1000, static_rt=None,
            exec_s=0.1, record_dir=None):
    wl = make_workload(kind, n_fns, duration_s=duration, n_cores=N_CORES,
                       seed=seed, exec_s=exec_s)
    pol = make_policy(policy, credit_window=window) if policy != "lags-static" \
        else make_policy(policy, static_rt_fns=static_rt)
    cfg = SimConfig(n_cores=N_CORES, hierarchy_depth=depth, burst_us=burst_us)
    r = simulate(wl, pol, cfg)
    if record_dir:
        from repro.obs.recorder import record_run

        record_run(
            record_dir,
            meta={"layer": "simkernel", "kind": kind, "n_fns": n_fns,
                  "policy": policy, "duration_s": duration, "seed": seed,
                  "depth": depth, "burst_us": burst_us},
            sched=r.sched_summary(),
            include_registry=False,
        )
    return r


@contextmanager
def timed(rows: list, name: str, derived: str = ""):
    t0 = time.time()
    yield
    rows.append((name, (time.time() - t0) * 1e6, derived))


def emit(rows):
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
