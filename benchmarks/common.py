"""Shared helpers for the per-figure benchmarks."""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro.core.policies import make_policy
from repro.core.simkernel import SimConfig, simulate
from repro.core.traces import make_workload

N_CORES = 12
DUR = 30.0  # seconds of simulated time per run (fast mode)


def run_sim(kind: str, n_fns: int, policy: str, *, duration=DUR, seed=1,
            depth=2.0, burst_us=120.0, window=1000, static_rt=None,
            exec_s=0.1, record_dir=None):
    wl = make_workload(kind, n_fns, duration_s=duration, n_cores=N_CORES,
                       seed=seed, exec_s=exec_s)
    pol = make_policy(policy, credit_window=window) if policy != "lags-static" \
        else make_policy(policy, static_rt_fns=static_rt)
    cfg = SimConfig(n_cores=N_CORES, hierarchy_depth=depth, burst_us=burst_us)
    r = simulate(wl, pol, cfg)
    if record_dir:
        from repro.obs.recorder import record_run

        record_run(
            record_dir,
            meta={"layer": "simkernel", "kind": kind, "n_fns": n_fns,
                  "policy": policy, "duration_s": duration, "seed": seed,
                  "depth": depth, "burst_us": burst_us},
            sched=r.sched_summary(),
            include_registry=False,
        )
    return r


def run_sim_jax(kind: str, n_fns: int, policy: str, *, duration=DUR, seed=1,
                depth=2.0, burst_us=120.0, window=1000, static_rt=None,
                exec_s=0.1, threads_per_fn=4, n_cores=N_CORES):
    """Same sweep on the ``lax.scan`` backend (any registered policy).

    Returns ``(latencies, outputs)``; policy names resolve through
    ``repro.sched.jax_backend.CODE_OF``, so every protocol policy — not
    just cfs/lags — runs under one jitted scan body.
    """
    from repro.core import simkernel_jax as sj
    from repro.sched.jax_backend import CODE_OF

    wl = make_workload(kind, n_fns, duration_s=duration, n_cores=n_cores,
                       seed=seed, exec_s=exec_s, threads_per_fn=threads_per_fn)
    trace = sj.build_slot_trace(wl, n_fns, threads_per_fn)
    p = sj.SimParams(
        n_cores=n_cores, n_fns=n_fns, n_ticks=int(duration / sj.TICK),
        policy=CODE_OF[policy], burst_us=burst_us, depth=depth,
        window_ticks=window,
        rt_fns=() if static_rt is None
        else tuple(int(f) for f in static_rt),
    )
    out = sj.simulate(trace, p)
    return sj.latencies_from(trace, out["done_tick"]), out


@contextmanager
def timed(rows: list, name: str, derived: str = ""):
    t0 = time.time()
    yield
    rows.append((name, (time.time() - t0) * 1e6, derived))


def emit(rows):
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.0f},{derived}")
