"""Benchmark harness: one module per paper table/figure + the TPU-serving
integration and the roofline analysis.  Prints ``name,us_per_call,derived``
CSV rows (us_per_call = harness wall time per run; derived = the figure's
metrics)."""
from __future__ import annotations

import sys
import traceback

from benchmarks.common import emit


def main() -> None:
    from benchmarks import (
        fig3_overhead,
        fig5_static,
        fig6_window,
        fig7_cluster,
        fig8_cdfs,
        fig9_colocation,
        fig10_overhead,
        fig11_baselines,
        roofline,
        serving_lags,
    )

    rows = []
    modules = [
        ("fig3", lambda: fig3_overhead.main()),
        ("fig3-cluster", lambda: fig3_overhead.main(cluster_mode=True)),
        ("fig5", fig5_static.main),
        ("fig6", fig6_window.main),
        ("fig7", fig7_cluster.main),
        ("fig8", fig8_cdfs.main),
        ("fig9", fig9_colocation.main),
        ("fig10", fig10_overhead.main),
        ("fig11", fig11_baselines.main),
        ("serving", serving_lags.main),
        ("roofline", roofline.main),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    for name, fn in modules:
        if only and only not in name:
            continue
        try:
            rows.extend(fn())
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc(file=sys.stderr)
            rows.append((f"{name}.ERROR", 0.0, repr(e)[:120]))
    emit(rows)


if __name__ == "__main__":
    main()
