"""Analytic FLOPs / HBM-traffic models per (arch x shape).

Two FLOPs numbers per cell:
  * ``model_flops``  — MODEL_FLOPS = 6*N*D for training (N = params, dense;
    N_active for MoE), 2*N*D for inference forward; attention not included
    (the standard accounting the roofline "useful" ratio is defined against).
  * ``cell_flops``   — HLO-equivalent executed FLOPs: adds attention
    score/value matmuls, remat recompute (train forward counted twice),
    MoE router/dispatch/combine einsums, logit head, and head-padding waste.

Validated against ``compiled.cost_analysis()`` on reduced configs in
``tests/test_flops_model.py`` (within tolerance; XLA counts loop bodies once,
reduced configs use trip counts of 1-2 so the comparison is exact there).
"""
from __future__ import annotations

from repro.configs.base import SHAPES, get_config, layer_specs
from repro.models import model as model_lib
from repro.models.params import count_params


def param_count(arch: str) -> int:
    return count_params(model_lib.abstract_params(get_config(arch)))


def active_param_count(arch: str) -> int:
    """Params touched per token (MoE: top_k + shared experts only)."""
    cfg = get_config(arch)
    total = param_count(arch)
    if cfg.n_experts == 0:
        return total
    # subtract inactive routed experts on MoE layers
    n_moe_layers = sum(1 for s in layer_specs(cfg) if s.mlp == "moe")
    per_expert = 3 * cfg.d_model * cfg.d_ff_expert
    inactive = n_moe_layers * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


def _attn_flops(cfg, S_q: int, S_kv: int, batch: int, causal=True) -> float:
    """Score + value matmuls (2*2*B*H*Sq*Skv*D), causal halving."""
    specs = layer_specs(cfg)
    total = 0.0
    for s in specs:
        if s.kind != "attn":
            continue
        kv = S_kv if s.window is None else min(S_kv, s.window)
        frac = 0.5 if (causal and S_q == S_kv and s.window is None) else 1.0
        total += 4.0 * batch * cfg.n_heads * S_q * kv * cfg.head_dim * frac
    return total


def _moe_overhead_flops(cfg, tokens: float) -> float:
    """Router + dispatch/combine one-hot einsums (GShard path)."""
    if cfg.n_experts == 0:
        return 0.0
    n_moe = sum(1 for s in layer_specs(cfg) if s.mlp == "moe")
    E, K, M = cfg.n_experts, cfg.top_k, cfg.d_model
    gs = 256
    C = max(4, -(-int(gs * K * 1.25 / E) // 4) * 4) if gs > 1 else 1
    per_tok = 2 * M * E + 2 * 2 * M * E * C  # router + dispatch + combine
    return n_moe * tokens * per_tok


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    n_active = active_param_count(arch)
    if sh.step == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if sh.step == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * sh.global_batch


def cell_flops(arch: str, shape_name: str) -> float:
    """HLO-equivalent executed FLOPs (global, all devices)."""
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    n_active = active_param_count(arch)
    B, S = sh.global_batch, sh.seq_len
    if sh.step == "train":
        tokens = B * S
        # fwd + remat-fwd + bwd = 2 + 2+... : grads cost 2x fwd; full remat
        # recomputes fwd once -> 4x fwd matmul work REL 2*N*D
        mm = 2.0 * n_active * tokens * 4.0
        attn = _attn_flops(cfg, S, S, B) * 4.0
        moe = _moe_overhead_flops(cfg, tokens) * 4.0
        return mm + attn + moe
    if sh.step == "prefill":
        tokens = B * S
        return (
            2.0 * n_active * tokens
            + _attn_flops(cfg, S, S, B)
            + _moe_overhead_flops(cfg, tokens)
        )
    # decode
    return (
        2.0 * n_active * B
        + _attn_flops(cfg, 1, S, B, causal=False)
        + _moe_overhead_flops(cfg, B)
    )


def memory_bytes(arch: str, shape_name: str, n_dev: int = 256) -> float:
    """Per-device HBM traffic per step (bytes), analytic.

    Terms: parameter reads (weights stream from HBM once per matmul pass;
    fwd + remat-fwd + bwd for train), optimizer state read+write (train),
    KV/SSM-cache read+write (decode/prefill), activation traffic
    (approximated as 2 bytes x tokens x d_model x layers x passes —
    residual stream reads/writes; attention/MoE internals assumed
    fused/VMEM-resident between ops).
    """
    cfg = get_config(arch)
    sh = SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    n_params = param_count(arch)
    p_bytes = 2.0 * n_params / n_dev  # bf16, fully sharded

    if sh.step == "train":
        passes = 3.0  # fwd + remat fwd + bwd weight reads
        opt = (4.0 + 4.0) * 2.0 * n_params / n_dev  # m,v read+write fp32
        grads = 2.0 * 2.0 * n_params / n_dev
        tokens_dev = B * S / n_dev * 16  # batch sharded over data(+pod) only
        act = 2.0 * tokens_dev * cfg.d_model * cfg.n_layers * 4.0
        return p_bytes * passes + opt + grads + act
    if sh.step == "prefill":
        tokens_dev = B * S / n_dev * 16
        act = 2.0 * tokens_dev * cfg.d_model * cfg.n_layers
        cache_w = _cache_bytes(cfg, B, S) / n_dev
        return p_bytes + act + cache_w
    # decode: read whole cache + params each step
    cache_rw = 1.0 * _cache_bytes(cfg, B, S) / n_dev
    act = 2.0 * B / n_dev * 16 * cfg.d_model * cfg.n_layers * 2
    return p_bytes + cache_rw + act


def _cache_bytes(cfg, B: int, S: int) -> float:
    total = 0.0
    for s in layer_specs(cfg):
        if s.kind == "attn":
            total += 2.0 * B * S * cfg.n_kv_heads * cfg.head_dim * 2.0
        else:
            total += 4.0 * B * cfg.d_inner * cfg.ssm_state
    return total
