"""Fig 11: cgroup-aware task completion vs tuned baselines.

120 functions of identical work under resctl / resctl-parallel / resctl-mix,
comparing CFS, tuned CFS (100 ms slice), SCHED_RR, EEVDF, tuned EEVDF and
CFS-LAGS, plus the 12-function uncontended reference.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, run_sim

POLICIES = ("cfs", "cfs-tuned", "rr", "eevdf", "eevdf-tuned", "lags")
KINDS = ("resctl", "resctl-parallel", "resctl-mix")


def main() -> list:
    rows = []
    for kind in KINDS:
        t0 = time.time()
        base = run_sim(kind, 12, "cfs")
        rows.append((
            f"fig11.{kind}.12fn-cfs",
            (time.time() - t0) * 1e6,
            f"p50={base.pct(50):.3f};p95={base.pct(95):.3f}",
        ))
        for pol in POLICIES:
            t0 = time.time()
            r = run_sim(kind, 120, pol)
            rows.append((
                f"fig11.{kind}.120fn-{pol}",
                (time.time() - t0) * 1e6,
                f"p50={r.pct(50):.3f};p95={r.pct(95):.3f};"
                f"thr_slo={r.throughput_slo():.1f}",
            ))
    return rows


if __name__ == "__main__":
    emit(main())
