"""Fig 11: cgroup-aware task completion vs tuned baselines.

120 functions of identical work under resctl / resctl-parallel / resctl-mix,
comparing CFS, tuned CFS (100 ms slice), SCHED_RR, EEVDF, tuned EEVDF and
CFS-LAGS, plus the 12-function uncontended reference.

The second block repeats the sweep on the JAX ``lax.scan`` backend
(``--jax`` rows): every protocol policy kind — including SCHED_RR, EEVDF
and CFS-LAGS-static — now runs through ``repro.sched.jax_backend``
under one jitted scan body, which is what the cluster study shards.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, run_sim, run_sim_jax
from repro.core.traces import lightest_band_fns

POLICIES = ("cfs", "cfs-tuned", "rr", "eevdf", "eevdf-tuned", "lags")
KINDS = ("resctl", "resctl-parallel", "resctl-mix")
# the five policy kinds on the JAX backend (tuned variants share kinds)
JAX_POLICIES = ("cfs", "eevdf", "rr", "lags", "lags-static")


def main() -> list:
    rows = []
    for kind in KINDS:
        t0 = time.time()
        base = run_sim(kind, 12, "cfs")
        rows.append((
            f"fig11.{kind}.12fn-cfs",
            (time.time() - t0) * 1e6,
            f"p50={base.pct(50):.3f};p95={base.pct(95):.3f}",
        ))
        for pol in POLICIES:
            t0 = time.time()
            r = run_sim(kind, 120, pol)
            rows.append((
                f"fig11.{kind}.120fn-{pol}",
                (time.time() - t0) * 1e6,
                f"p50={r.pct(50):.3f};p95={r.pct(95):.3f};"
                f"thr_slo={r.throughput_slo():.1f}",
            ))
    # JAX sweep on the open-loop trace (the scan backend replays recorded
    # arrivals; closed-loop resctl load generation stays numpy-only)
    static = lightest_band_fns(120, n_bands_low=3)
    for pol in JAX_POLICIES:
        t0 = time.time()
        lat, _ = run_sim_jax(
            "azure2021", 120, pol,
            static_rt=static if pol == "lags-static" else None,
        )
        rows.append((
            f"fig11.jax.120fn-{pol}",
            (time.time() - t0) * 1e6,
            f"p50={np.median(lat) if len(lat) else -1:.3f};"
            f"p95={np.percentile(lat, 95) if len(lat) else -1:.3f};"
            f"n={len(lat)}",
        ))
    return rows


if __name__ == "__main__":
    emit(main())
