"""Failover: chaos-driven fault injection on the consolidated fleet.

The operational counterpart of the Fig 7 consolidation headline: packing
800 functions onto the 10-node LAGS fleet is only a win if the fleet
*recovers* when a node dies mid-run.  The scenario:

  * a single ``node_crash`` at t=20s of a 60s run (5s controller epochs);
  * with rebalancing, the controller detects the crash via missed
    heartbeats within one epoch, re-places the dead node's 80 functions
    onto the survivors (conservation-checked every epoch) and replays
    their stranded retry backlog on the new homes;
  * the static-placement baseline strands them for the remaining 40s —
    its backlog never drains (``lost_arrivals``).

All three runs (fault-free reference, crash+rebalance, crash+static) go
through the *same* epoched, work-conserving pipeline (unfinished work
carries across epoch boundaries) so boundary effects cancel out of the
comparison.  Acceptance (the repo's burst-recovery
SLO, ``tail_factor=1.4`` as in ``repro.fleet.consolidate``):

  * the rebalanced LAGS run recovers >= 99 % of the fault-free
    completions and keeps p95 within 1.4x the fault-free p95;
  * the static baseline breaches (loses ~40/60 * 1/10 ~ 6.7 % of
    completions);
  * an empty schedule is bit-identical to ``simulate_fleet`` (the
    chaos layer costs nothing when unused).

Also swept: CFS vs LAGS migration pricing (a migration pays the policy's
own voluntary-switch cost at the destination density — CFS migrations
into dense survivors are costlier) and a random multi-fault schedule.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.fleet import (
    CLUSTER_EXEC_S,
    FaultSchedule,
    make_policy,
    migration_cost_s,
    place,
    simulate_fleet,
    simulate_fleet_chaos,
)

TOTAL_FNS = 800
N_NODES = 10  # the consolidated LAGS fleet (Fig 7)
DURATION_S = 60.0
EPOCH_S = 5.0
CRASH_T = 20.0
CRASH_NODE = 3
SLO_TAIL_FACTOR = 1.4  # burst-recovery SLO (consolidate.min_nodes_meeting_slo)
RECOVER_FRAC = 0.99


def _chaos(policy: str, asg, schedule, rebalance: bool):
    return simulate_fleet_chaos(
        policy, asg, schedule, duration_s=DURATION_S, epoch_s=EPOCH_S,
        exec_s=CLUSTER_EXEC_S, rebalance=rebalance,
    )


def main() -> list:
    rows = []

    # differential: empty schedule + no epoching == simulate_fleet, bit-exact
    asg = place("round-robin", TOTAL_FNS, N_NODES, exec_s=CLUSTER_EXEC_S)
    base = simulate_fleet("lags", asg, duration_s=12.0, exec_s=CLUSTER_EXEC_S)
    chaos0 = simulate_fleet_chaos(
        "lags", asg, FaultSchedule.empty(N_NODES), duration_s=12.0,
        exec_s=CLUSTER_EXEC_S,
    )
    identical = (
        np.array_equal(base.latencies, chaos0.latencies)
        and base.n_arrived == chaos0.n_arrived
        and base.n_completed == chaos0.n_completed
    )
    rows.append((
        "fig_failover.differential", 0.0,
        f"empty_schedule_bit_identical={'PASS' if identical else 'FAIL'}",
    ))

    # crash scenario on the consolidated fleet under ``spread`` — the
    # load-balanced placement the rebalancer itself uses, so pre- and
    # post-failover placement quality match
    asg_c = place("spread", TOTAL_FNS, N_NODES, exec_s=CLUSTER_EXEC_S)
    crash = FaultSchedule.single_crash(CRASH_NODE, CRASH_T, N_NODES)
    for policy in ("lags", "cfs"):
        t0 = time.time()
        ref = _chaos(policy, asg_c, FaultSchedule.empty(N_NODES), True)
        reb = _chaos(policy, asg_c, crash, True)
        stat = _chaos(policy, asg_c, crash, False)
        us = (time.time() - t0) * 1e6 / 3

        p95_slo = SLO_TAIL_FACTOR * ref.pct(95)
        rows.append((
            f"fig_failover.ref.{policy}", us,
            f"completed={ref.n_completed};p95={ref.pct(95):.3f};"
            f"done={ref.done_ratio * 100:.1f}%",
        ))
        rec = reb.recovery_s().get(CRASH_NODE)
        rows.append((
            f"fig_failover.crash.rebalance.{policy}", us,
            f"completed={reb.n_completed};p95={reb.pct(95):.3f};"
            f"recovered={reb.n_completed / ref.n_completed * 100:.2f}%;"
            f"recovery_s={rec if rec is not None else 'never'};"
            f"migrations={len(reb.migrations)};"
            f"migration_s={reb.migration_s:.4f};"
            f"stranded={reb.stranded_arrivals};"
            f"replayed={reb.replayed_arrivals};"
            f"lost={reb.lost_arrivals};"
            f"slo_degraded={reb.degraded_slo_attainment() * 100:.1f}%",
        ))
        srec = stat.recovery_s().get(CRASH_NODE)
        rows.append((
            f"fig_failover.crash.static.{policy}", us,
            f"completed={stat.n_completed};p95={stat.pct(95):.3f};"
            f"recovered={stat.n_completed / ref.n_completed * 100:.2f}%;"
            f"recovery_s={srec if srec is not None else 'never'};"
            f"stranded={stat.stranded_arrivals};"
            f"lost={stat.lost_arrivals};"
            f"slo_degraded={stat.degraded_slo_attainment() * 100:.1f}%",
        ))
        # the SLO verdict is about the consolidated LAGS fleet (Fig 7);
        # the CFS sweep is the comparison point — its rebalanced run lands
        # just under the bar because migrations and context switches both
        # price higher at the post-failover density of ~89 cgroups/node,
        # the same per-switch asymmetry the paper measures
        if policy == "lags":
            reb_ok = (
                reb.n_completed >= RECOVER_FRAC * ref.n_completed
                and reb.pct(95) <= p95_slo
            )
            stat_breach = stat.n_completed < RECOVER_FRAC * ref.n_completed
            rows.append((
                f"fig_failover.verdict.{policy}", 0.0,
                f"rebalance_meets_slo={'PASS' if reb_ok else 'FAIL'};"
                f"static_breaches={'PASS' if stat_breach else 'FAIL'};"
                f"p95_slo={p95_slo:.3f}",
            ))

    # migration pricing asymmetry: the policy's own switch-cost model at
    # the destination density (88 colocated cgroups post-failover)
    dens = TOTAL_FNS // N_NODES + TOTAL_FNS // N_NODES // (N_NODES - 1)
    c_cfs = migration_cost_s(make_policy("cfs"), dens)
    c_lags = migration_cost_s(make_policy("lags"), dens)
    ratio = ("inf" if c_lags < 1e-9
             else f"{c_cfs / c_lags:.1f}x")  # LAGS run-to-completion: ~free
    rows.append((
        "fig_failover.migration_cost", 0.0,
        f"dest_groups={dens};cfs_s={c_cfs:.5f};lags_s={c_lags:.5f};"
        f"ratio={ratio}",
    ))

    # robustness: a random multi-fault schedule (crashes + slowdowns +
    # storm) still conserves functions and keeps serving
    t0 = time.time()
    rnd = FaultSchedule.random(seed=11, n_nodes=N_NODES,
                               duration_s=DURATION_S, n_events=5)
    res = _chaos("lags", asg_c, rnd, True)
    us = (time.time() - t0) * 1e6
    rows.append((
        "fig_failover.random.lags", us,
        f"events={len(rnd)};migrations={len(res.migrations)};"
        f"done={res.done_ratio * 100:.1f}%;"
        f"completed={res.n_completed};lost={res.lost_arrivals}",
    ))
    return rows


if __name__ == "__main__":
    emit(main())
