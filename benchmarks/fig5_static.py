"""Fig 5: CFS-LAGS-static (SCHED_RR for the lowest demand bands) vs CFS —
per-group latency CDFs under 100-function cluster-mode colocation (§4.1).

Checks the paper's counter-intuitive result: prioritising group-low also
improves group-high, via >75 % less run-queue waiting overall.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, run_sim
from repro.core.traces import demand_band_of, lightest_band_fns

N_FNS = 100


def main() -> list:
    rows = []
    static = lightest_band_fns(N_FNS, n_bands_low=3)
    band = demand_band_of(N_FNS)
    for pol in ("cfs", "lags-static"):
        t0 = time.time()
        r = run_sim("azure2021", N_FNS, pol, depth=5.0, burst_us=280.0,
                    exec_s=0.2, static_rt=static)
        is_low = np.isin(r.fn_of, static)
        lo = r.latencies[is_low]
        hi = r.latencies[~is_low]
        rows.append((
            f"fig5.{pol}",
            (time.time() - t0) * 1e6,
            (
                f"low_p50={np.median(lo) if len(lo) else -1:.3f};"
                f"low_p95={np.percentile(lo,95) if len(lo) else -1:.3f};"
                f"high_p50={np.median(hi) if len(hi) else -1:.3f};"
                f"high_p95={np.percentile(hi,95) if len(hi) else -1:.3f}"
            ),
        ))
    return rows


if __name__ == "__main__":
    emit(main())
