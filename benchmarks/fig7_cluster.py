"""Fig 7 / §5.1: cluster consolidation — CFS vs CFS-LAGS minimum node count.

Paper: 14 nodes (CFS, static reservation) -> 10 nodes (LAGS), a 28 %
reduction; safe utilisation 45 % -> 55 %; perceived-vs-effective CPU gap
+100 % (CFS) -> +10 % (LAGS).

Thin driver over :mod:`repro.fleet`: the consolidation search, placement
strategies and multi-node simulation (numpy per-node loop and the vmapped
``lax.scan`` fleet) all live there, as does the workload calibration that
anchors the 14-node static-reservation baseline at the paper's ~45-50 %
utilisation (see ``repro.fleet.consolidate``).  Reported here:

  * the headline sweep (round-robin placement, conserving the full 800
    functions — the legacy path silently floored the per-node share);
  * pack vs spread vs round-robin vs switch-aware at the LAGS minimum
    node count, with per-node imbalance columns;
  * a JAX cross-check where each configuration's nodes run as one vmapped
    scan (one compile per node-count).
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.fleet import (
    consolidation_sweep,
    min_nodes_meeting_slo,
    placement_comparison,
)


def main() -> list:
    rows = []
    t0 = time.time()
    res = consolidation_sweep(total_fns=800,
                              node_counts=(15, 14, 13, 12, 11, 10, 9, 8))
    us = (time.time() - t0) * 1e6
    for r in res:
        rows.append((
            f"fig7.{r.policy}.n{r.n_nodes}",
            us / len(res),
            (
                f"p50={r.p50:.3f};p95={r.p95:.3f};"
                f"done={r.done_ratio*100:.1f}%;"
                f"util_eff={r.util_effective*100:.0f}%;"
                f"util_perc={r.util_perceived*100:.0f}%;"
                f"ovh={r.overhead_frac*100:.1f}%"
            ),
        ))
    n_cfs = min_nodes_meeting_slo(res, "cfs")
    n_lags = min_nodes_meeting_slo(res, "lags")
    rows.append((
        "fig7.consolidation",
        0.0,
        (
            f"min_nodes_cfs={n_cfs};min_nodes_lags={n_lags};"
            f"reduction={100*(1-n_lags/max(n_cfs,1)):.1f}%"
        ),
    ))

    # placement quality at the consolidated node count: same workload and
    # policy, different packing — per-node imbalance is the story
    t0 = time.time()
    pres = placement_comparison(total_fns=800, n_nodes=n_lags, policy="lags")
    us = (time.time() - t0) * 1e6
    for r in pres:
        rows.append((
            f"fig7.place.{r.placement}.n{r.n_nodes}",
            us / len(pres),
            (
                f"p95={r.p95:.3f};p95_spread={r.p95_spread:.3f};"
                f"ovh={r.overhead_frac*100:.1f}%;"
                f"ovh_imb={r.ovh_max_over_mean:.2f}"
            ),
        ))

    # cross-check on the lax.scan backend: every node of a configuration
    # batched into one vmapped scan (one compile per node count); the same
    # SLO search runs backend-blind over the per-node SimResults
    t0 = time.time()
    res_jax = consolidation_sweep(
        total_fns=800, node_counts=(14, 12, 10), backend="jax",
        duration_s=30.0,
    )
    us = (time.time() - t0) * 1e6
    for r in res_jax:
        rows.append((
            f"fig7.jax.{r.policy}.n{r.n_nodes}",
            us / len(res_jax),
            f"p50={r.p50:.3f};p95={r.p95:.3f};ovh={r.overhead_frac*100:.1f}%",
        ))
    return rows


if __name__ == "__main__":
    emit(main())
