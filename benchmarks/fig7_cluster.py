"""Fig 7 / §5.1: cluster consolidation — CFS vs CFS-LAGS minimum node count.

Paper: 14 nodes (CFS, static reservation) -> 10 nodes (LAGS), a 28 %
reduction; safe utilisation 45 % -> 55 %; perceived-vs-effective CPU gap
+100 % (CFS) -> +10 % (LAGS).
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.cluster import consolidation_sweep, min_nodes_meeting_slo


def main() -> list:
    rows = []
    t0 = time.time()
    res = consolidation_sweep(total_fns=800,
                              node_counts=(15, 14, 13, 12, 11, 10, 9, 8))
    us = (time.time() - t0) * 1e6
    for r in res:
        rows.append((
            f"fig7.{r.policy}.n{r.n_nodes}",
            us / len(res),
            (
                f"p50={r.p50:.3f};p95={r.p95:.3f};"
                f"util_eff={r.util_effective*100:.0f}%;"
                f"util_perc={r.util_perceived*100:.0f}%;"
                f"ovh={r.overhead_frac*100:.1f}%"
            ),
        ))
    n_cfs = min_nodes_meeting_slo(res, "cfs")
    n_lags = min_nodes_meeting_slo(res, "lags")
    rows.append((
        "fig7.consolidation",
        0.0,
        (
            f"min_nodes_cfs={n_cfs};min_nodes_lags={n_lags};"
            f"reduction={100*(1-n_lags/max(n_cfs,1)):.0f}%"
        ),
    ))
    # cross-check on the lax.scan backend (jit per node count; the same
    # SLO search runs backend-blind over SimResult)
    t0 = time.time()
    res_jax = consolidation_sweep(
        total_fns=800, node_counts=(14, 12, 10), backend="jax"
    )
    us = (time.time() - t0) * 1e6
    for r in res_jax:
        rows.append((
            f"fig7.jax.{r.policy}.n{r.n_nodes}",
            us / len(res_jax),
            f"p50={r.p50:.3f};p95={r.p95:.3f};ovh={r.overhead_frac*100:.1f}%",
        ))
    return rows


if __name__ == "__main__":
    emit(main())
