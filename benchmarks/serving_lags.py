"""Beyond-paper: LAGS admission in the multi-tenant TPU serving engine.

Density sweep over tenant count on one serving slice (DESIGN.md §2):
LAGS vs fair vs fifo admission under bursty heavy-tailed tenant demand,
measuring SLO attainment, median latency and engine switch overhead
(weight-swap residency misses + batch re-formation).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.traces import _mmpp_arrivals
from repro.scheduler.tenant import Request, Tenant
from repro.serving.engine import Engine, EngineConfig


def run_engine(policy: str, n_tenants: int, seed: int = 0, dur: float = 60.0):
    rng = np.random.default_rng(seed)
    tenants = {
        i: Tenant(i, weight_mb=float(rng.uniform(32, 256)))
        for i in range(n_tenants)
    }
    rates = np.logspace(-1, 0.8, n_tenants)
    rates *= 28.0 / rates.sum()
    arrivals, rid = [], 0
    for t in range(n_tenants):
        for a in _mmpp_arrivals(rates[t], dur, rng, burst_on=1.0, burst_off=9.0):
            arrivals.append(
                Request(rid, t, int(rng.integers(64, 512)),
                        int(rng.integers(16, 128)), float(a))
            )
            rid += 1
    eng = Engine(EngineConfig(policy=policy, max_resident=12), tenants)
    st = eng.run(dur, arrivals)
    lat = np.asarray([r.latency for r in st.completed])
    return st, lat, rid


def main(densities=(24, 48, 96)) -> list:
    rows = []
    for n in densities:
        for pol in ("fifo", "fair", "lags"):
            t0 = time.time()
            st, lat, total = run_engine(pol, n)
            rows.append((
                f"serving.t{n}.{pol}",
                (time.time() - t0) * 1e6,
                (
                    f"done={len(st.completed)}/{total};"
                    f"p50={np.median(lat) if len(lat) else -1:.2f};"
                    f"slo2s={100*np.mean(lat<2.0) if len(lat) else 0:.0f}%;"
                    f"ovh={st.overhead_frac*100:.1f}%"
                ),
            ))
    return rows


if __name__ == "__main__":
    emit(main())
