"""Roofline analysis per (arch x shape x mesh) from the dry-run artifacts.

Three terms per cell (EXPERIMENTS.md §Roofline):

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs            (197 Tf bf16)
  memory_s     = HBM_bytes_per_device / HBM_bw                (819 GB/s)
  collective_s = wire_bytes_per_device / (links * link_bw)    (~50 GB/s/link)

Caveat handled here: XLA's ``cost_analysis`` counts a while-loop body ONCE —
scan-over-layers / microbatch / loss-chunk loops must be re-multiplied by
their trip counts.  ``hlo_stats.analyze_hlo`` parses the partitioned HLO,
builds the computation call graph, extracts each while loop's trip count
from its condition, and attributes per-computation FLOPs (dot/conv fusions
are NOT re-derivable from text, so FLOPs use the analytic per-arch model in
``flops_model``; bytes and collective wire volumes are parsed from the HLO
with trip multipliers).

MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE); the ratio
MODEL_FLOPS / HLO_FLOPs shows how much compiled compute is useful
(catching remat recompute + MoE dispatch overhead).
"""
from __future__ import annotations

import json
import sys
import time

from benchmarks.flops_model import cell_flops, model_flops
from benchmarks.hlo_stats import CHIP, roofline_terms


def main(report_path: str = "dryrun_report.json") -> list:
    rows = []
    try:
        cells = json.load(open(report_path))
    except FileNotFoundError:
        print(f"# {report_path} missing - run: "
              "PYTHONPATH=src python -m repro.launch.dryrun --all --out "
              "dryrun_report.json", file=sys.stderr)
        return [("roofline.skipped", 0.0, "no dryrun_report.json")]
    for c in cells:
        if c.get("status") != "ok":
            continue
        t0 = time.time()
        terms = roofline_terms(c)
        hlo_f = cell_flops(c["arch"], c["shape"])
        mf = model_flops(c["arch"], c["shape"])
        n_dev = 512 if c["mesh"] == "2x16x16" else 256
        comp_s = hlo_f / n_dev / CHIP.peak_flops
        dom = max(
            ("compute", comp_s),
            ("memory", terms["memory_s"]),
            ("collective", terms["collective_s"]),
            key=lambda kv: kv[1],
        )[0]
        rows.append((
            f"roofline.{c['arch']}.{c['shape']}.{c['mesh']}",
            (time.time() - t0) * 1e6,
            (
                f"compute_s={comp_s:.4f};memory_s={terms['memory_s']:.4f};"
                f"collective_s={terms['collective_s']:.4f};dominant={dom};"
                f"model_flops={mf:.3e};hlo_flops={hlo_f:.3e};"
                f"useful={mf/max(hlo_f,1e-9)*100:.0f}%"
            ),
        ))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_report.json"))
