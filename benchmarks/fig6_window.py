"""Fig 6: Load Credit EMA window sweep (100 fns, cluster mode).

The paper finds ~1000 ticks (4 s at CONFIG_HZ=250) best; too small degrades
toward CFS (no run-to-completion), too large staves off heavy groups.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, run_sim

WINDOWS = (10, 100, 500, 1000, 2500, 5000)


def main() -> list:
    rows = []
    for w in WINDOWS:
        t0 = time.time()
        r = run_sim("azure2021", 100, "lags", depth=5.0, burst_us=280.0,
                    exec_s=0.2, window=w)
        rows.append((
            f"fig6.window{w}",
            (time.time() - t0) * 1e6,
            f"p50={r.pct(50):.3f};p95={r.pct(95):.3f};"
            f"thr_slo={r.throughput_slo():.1f}",
        ))
    return rows


if __name__ == "__main__":
    emit(main())
