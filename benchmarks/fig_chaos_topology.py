"""Topology-aware chaos: rack crashes, partitions, proactive drain.

Extends ``fig_failover`` with the correlated/network failure patterns the
ROADMAP lists as the open chaos-coverage gap, on the same consolidated
800-fn/10-node LAGS fleet (Fig 7).  Three stories:

  * **rack crash, proactive vs reactive** — rack 2's two nodes start
    trending degraded (1.8x slowdown, *below* the straggler watchdog's
    ``min_ratio`` so reactive quarantine never fires) before the whole
    rack loses power at t=30s.  The topology-aware config
    (``rack-spread`` placement + proactive drain) notices the trend and
    evacuates both nodes *before* the crash, so nothing is stranded when
    the rack dies: strictly lower ``recovery_s`` and higher
    degraded-window SLO attainment than the reactive config, which can
    only rebalance after detecting the crash.  (Heartbeat delay/loss on
    a later-crashing node would fence it and correctly *veto* the drain
    — a fenced node's functions must not be moved; that interaction is
    pinned by the unit tests, not swept here.)
  * **pure partition** — rack 1's nodes stop heartbeating for 10s but
    keep serving.  The evidence-based tracker holds them at SUSPECT, the
    controller *fences* them (defers their new arrivals, lets in-flight
    work complete) instead of double-placing their functions: zero
    migrations, per-epoch conservation holds throughout, and every
    deferred arrival is replayed after the heal (``lost == 0``).
  * **differential** — an empty schedule with no topology still delegates
    bit-identically to ``simulate_fleet`` (the topology layer costs
    nothing when unused).

Acceptance is encoded in the verdict rows (all must be PASS).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.fleet import (
    CLUSTER_EXEC_S,
    FaultEvent,
    FaultSchedule,
    Topology,
    place,
    simulate_fleet,
    simulate_fleet_chaos,
)

TOTAL_FNS = 800
N_NODES = 10  # the consolidated LAGS fleet (Fig 7)
RACK_SIZE = 2  # 5 racks of 2
DURATION_S = 60.0
EPOCH_S = 5.0
CRASH_RACK = 2  # nodes 4, 5
CRASH_T = 30.0
PART_NODES = (2, 3)  # rack 1
PART_T = 20.0
PART_DUR = 10.0


def _rack_story_schedule(topo: Topology) -> FaultSchedule:
    """Rack 2 trends degraded (a moderate slowdown, below the reactive
    watchdog's trigger) and then loses power outright."""
    evs = [FaultEvent(5.0, "node_slow", n, factor=1.8)
           for n in topo.nodes_in(CRASH_RACK)]
    evs.append(FaultEvent(CRASH_T, "rack_crash", rack=CRASH_RACK))
    return FaultSchedule(evs, N_NODES, topo)


def main() -> list:
    rows = []
    topo = Topology.uniform(N_NODES, RACK_SIZE)

    # (c) differential: no topology + empty schedule == simulate_fleet
    asg = place("round-robin", TOTAL_FNS, N_NODES, exec_s=CLUSTER_EXEC_S)
    base = simulate_fleet("lags", asg, duration_s=12.0, exec_s=CLUSTER_EXEC_S)
    chaos0 = simulate_fleet_chaos(
        "lags", asg, FaultSchedule.empty(N_NODES), duration_s=12.0,
        exec_s=CLUSTER_EXEC_S,
    )
    identical = (
        np.array_equal(base.latencies, chaos0.latencies)
        and base.n_arrived == chaos0.n_arrived
        and base.n_completed == chaos0.n_completed
    )
    rows.append((
        "fig_chaos_topology.differential", 0.0,
        f"no_topology_bit_identical={'PASS' if identical else 'FAIL'}",
    ))

    # (a) rack crash: rack-spread + proactive drain vs reactive rebalance
    sched = _rack_story_schedule(topo)
    kw = dict(duration_s=DURATION_S, epoch_s=EPOCH_S, exec_s=CLUSTER_EXEC_S,
              topology=topo)
    asg_topo = place("rack-spread", TOTAL_FNS, N_NODES,
                     exec_s=CLUSTER_EXEC_S, racks=topo.racks())
    asg_flat = place("spread", TOTAL_FNS, N_NODES, exec_s=CLUSTER_EXEC_S)
    t0 = time.time()
    # enter at 1.35x the fleet mean: with *both* rack-2 nodes slowed the
    # non-draining fleet mean is itself inflated by the other slow node,
    # so the default 1.6x ratio would not trip until after the crash
    pro = simulate_fleet_chaos("lags", asg_topo, sched,
                               proactive_drain=True,
                               drain_enter_ratio=1.35,
                               drain_exit_ratio=1.15, **kw)
    rea = simulate_fleet_chaos("lags", asg_flat, sched,
                               proactive_drain=False, **kw)
    us = (time.time() - t0) * 1e6 / 2
    pro_rec, rea_rec = pro.max_recovery_s(), rea.max_recovery_s()
    pro_slo = pro.degraded_slo_attainment()
    rea_slo = rea.degraded_slo_attainment()
    drained = sorted({n for e in pro.epochs for n in e.draining})
    rows.append((
        "fig_chaos_topology.rack.proactive", us,
        f"completed={pro.n_completed};recovery_s={pro_rec};"
        f"slo_degraded={pro_slo * 100:.2f}%;"
        f"drained={drained};"
        f"migrations={len(pro.migrations)};lost={pro.lost_arrivals}",
    ))
    rows.append((
        "fig_chaos_topology.rack.reactive", us,
        f"completed={rea.n_completed};recovery_s={rea_rec};"
        f"slo_degraded={rea_slo * 100:.2f}%;"
        f"migrations={len(rea.migrations)};lost={rea.lost_arrivals}",
    ))
    rack_ok = (
        pro_rec is not None and rea_rec is not None and pro_rec < rea_rec
        and pro_slo > rea_slo
    )
    rows.append((
        "fig_chaos_topology.verdict.rack", 0.0,
        f"proactive_strictly_faster={'PASS' if rack_ok else 'FAIL'};"
        f"recovery_s={pro_rec}vs{rea_rec};"
        f"slo={pro_slo * 100:.2f}%vs{rea_slo * 100:.2f}%",
    ))

    # (b) pure partition: fencing, conservation, reconcile-on-heal
    part = FaultSchedule.single_partition(
        PART_NODES, PART_T, PART_DUR, N_NODES, topo)
    t0 = time.time()
    res = simulate_fleet_chaos("lags", asg_topo, part, **kw)
    us = (time.time() - t0) * 1e6
    conserved = all(sum(e.counts) == TOTAL_FNS for e in res.epochs)
    reconciled = (res.lost_arrivals == 0
                  and res.replayed_arrivals >= res.deferred_arrivals > 0)
    suspects = sorted({n for e in res.epochs for n in e.suspects})
    fenced = sorted({n for e in res.epochs for n in e.fenced})
    rows.append((
        "fig_chaos_topology.partition", us,
        f"completed={res.n_completed};done={res.done_ratio * 100:.2f}%;"
        f"suspects={suspects};"
        f"fenced={fenced};"
        f"deferred={res.deferred_arrivals};"
        f"replayed={res.replayed_arrivals};"
        f"reconciled={res.reconciled_completions};"
        f"migrations={len(res.migrations)};lost={res.lost_arrivals}",
    ))
    rows.append((
        "fig_chaos_topology.verdict.partition", 0.0,
        f"no_double_placement={'PASS' if not res.migrations else 'FAIL'};"
        f"conserved_every_epoch={'PASS' if conserved else 'FAIL'};"
        f"reconciled_on_heal={'PASS' if reconciled else 'FAIL'}",
    ))
    return rows


if __name__ == "__main__":
    emit(main())
