"""Fig 10: scheduling overhead with increasing colocation — CFS vs LAGS.
Paper: LAGS cuts mean switch cost 21 -> ~13 us and rate by ~13 %."""
from __future__ import annotations

import time

from benchmarks.common import DUR, N_CORES, emit, run_sim

DENSITIES = (9, 13, 19)


def main() -> list:
    rows = []
    ref = {}
    for d in DENSITIES:
        for pol in ("cfs", "lags"):
            t0 = time.time()
            r = run_sim("azure2021", d * N_CORES, pol)
            ref[(pol, d)] = r
            rows.append((
                f"fig10.{pol}.d{d}",
                (time.time() - t0) * 1e6,
                f"ovh={r.overhead_frac*100:.1f}%;"
                f"switch_us={r.mean_switch_cost_us:.1f};"
                f"sw_per_s={r.switches/DUR:.0f}",
            ))
    c, l = ref[("cfs", 19)], ref[("lags", 19)]
    rows.append((
        "fig10.summary.d19",
        0.0,
        (
            f"cost_cfs={c.mean_switch_cost_us:.1f}us;"
            f"cost_lags={l.mean_switch_cost_us:.1f}us;"
            f"rate_drop={100*(1-l.switches/max(c.switches,1)):.0f}%"
        ),
    ))
    return rows


if __name__ == "__main__":
    emit(main())
