"""Fig 10: scheduling overhead with increasing colocation — CFS vs LAGS.
Paper: LAGS cuts mean switch cost 21 -> ~13 us and rate by ~13 %.

Telemetry is on for every run: the summary row is schedstat-backed (per-
switch cost tails and switch-time share per policy), and ``--obs-dir DIR``
records one run record per (policy, density) so any pair can be diffed with
``python -m repro.obs.report --diff``.
"""
from __future__ import annotations

import os
import sys
import time

import repro.obs as obs
from benchmarks.common import DUR, N_CORES, emit, run_sim

DENSITIES = (9, 13, 19)


def main(obs_dir: str = "") -> list:
    obs.enable()
    rows = []
    ref = {}
    for d in DENSITIES:
        for pol in ("cfs", "lags"):
            t0 = time.time()
            rec = os.path.join(obs_dir, f"{pol}_d{d}") if obs_dir else None
            r = run_sim("azure2021", d * N_CORES, pol, record_dir=rec)
            ref[(pol, d)] = r
            s = r.sched_summary()
            rows.append((
                f"fig10.{pol}.d{d}",
                (time.time() - t0) * 1e6,
                f"ovh={r.overhead_frac*100:.1f}%;"
                f"switch_us={r.mean_switch_cost_us:.1f};"
                f"sw_per_s={r.switches/DUR:.0f};"
                f"p99sw_us={s.switch_cost_us.pct(99):.1f}",
            ))
    c, l = ref[("cfs", 19)], ref[("lags", 19)]
    sc, sl = c.sched_summary(), l.sched_summary()
    rows.append((
        "fig10.summary.d19",
        0.0,
        (
            f"cost_cfs={c.mean_switch_cost_us:.1f}us;"
            f"cost_lags={l.mean_switch_cost_us:.1f}us;"
            f"rate_drop={100*(1-l.switches/max(c.switches,1)):.0f}%;"
            f"share_cfs={sc.switch_share*100:.1f}%;"
            f"share_lags={sl.switch_share*100:.1f}%"
        ),
    ))
    return rows


if __name__ == "__main__":
    argv = sys.argv[1:]
    out = argv[argv.index("--obs-dir") + 1] if "--obs-dir" in argv else ""
    emit(main(obs_dir=out))
