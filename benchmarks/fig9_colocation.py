"""Fig 9: throughput + median/tail latency as colocation increases
(azure2021), CFS vs CFS-LAGS.  The paper's headline: CFS's ideal density is
8x; LAGS accommodates at least +12 more functions at the 1 s target and
holds overload degradation to <10 % (vs 35 %)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import DUR, N_CORES, emit, run_sim

DENSITIES = (3, 6, 8, 9, 10, 11, 13, 16, 19)


def main() -> list:
    rows = []
    peak = {}
    for pol in ("cfs", "lags"):
        for d in DENSITIES:
            t0 = time.time()
            r = run_sim("azure2021", d * N_CORES, pol)
            thr = r.throughput_slo()
            peak[pol] = max(peak.get(pol, 0.0), thr)
            rows.append((
                f"fig9.{pol}.d{d}",
                (time.time() - t0) * 1e6,
                f"thr_slo={thr:.1f};p50={r.pct(50):.3f};p95={r.pct(95):.3f}",
            ))
        last = [float(x[2].split("thr_slo=")[1].split(";")[0])
                for x in rows if x[0].startswith(f"fig9.{pol}.d19")][0]
        rows.append((
            f"fig9.{pol}.degradation",
            0.0,
            f"peak={peak[pol]:.1f};at19x={last:.1f};"
            f"drop={100*(1-last/max(peak[pol],1e-9)):.0f}%",
        ))
    return rows


if __name__ == "__main__":
    emit(main())
