"""The Load Credit metric (paper §4.2, Appendix A.2).

Load Credit is tracked per cgroup (= serverless function = serving tenant):

  1. PELT-style load average: geometric decay with a 32 ms half-life
     (Linux's ``tg->load_avg``), updated every scheduler tick with the
     fraction of CPU the group consumed during that tick.
  2. Load Credit = exponential moving average of the PELT load over a much
     larger window (``tg_load_avg_ema_window`` ticks; paper Fig 6 best value
     1000 ticks = 4 s at CONFIG_HZ=250) — Linux's new ``tg->load_avg_ema``.

CFS-LAGS orders group scheduling entities by *ascending* Load Credit: the
group that has consumed the least CPU recently runs first and keeps running
until a lighter group wakes (Least-Attained-Service over the credit window).

Both a numpy implementation (used by the simulators and the serving engine
control plane) and a JAX implementation (used by the lax.scan tick simulator
and the ``lags_select`` TPU kernel's reference) are provided; they are
bit-identical in float64 and allclose in float32.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

TICK_SEC = 0.004  # CONFIG_HZ = 250
PELT_HALFLIFE_TICKS = 8  # 32 ms
DEFAULT_EMA_WINDOW = 1000  # ticks  (paper Fig 6: best latency at 1000)


def pelt_decay(halflife_ticks: int = PELT_HALFLIFE_TICKS) -> float:
    return 0.5 ** (1.0 / halflife_ticks)


def pelt_update(load_avg, running_frac, y: float | None = None):
    """One tick of PELT: load <- y*load + (1-y)*running_frac.

    running_frac: fraction of one CPU the group consumed this tick (can
    exceed 1.0 on multicore — Linux sums per-CPU contributions).
    """
    y = pelt_decay() if y is None else y
    return y * load_avg + (1.0 - y) * running_frac


def ema_update(ema, load_avg, window_ticks: int = DEFAULT_EMA_WINDOW):
    """One tick of the Load Credit EMA (tg->load_avg_ema)."""
    alpha = 2.0 / (window_ticks + 1.0)
    return (1.0 - alpha) * ema + alpha * load_avg


@dataclass
class LoadCreditTracker:
    """Vectorised Load Credit state over ``n_groups`` cgroups."""

    n_groups: int
    window_ticks: int = DEFAULT_EMA_WINDOW
    pelt_halflife: int = PELT_HALFLIFE_TICKS

    def __post_init__(self):
        self.load_avg = np.zeros(self.n_groups)
        self.credit = np.zeros(self.n_groups)
        self._y = pelt_decay(self.pelt_halflife)

    def tick(self, running_frac: np.ndarray) -> np.ndarray:
        """Advance one tick given per-group CPU consumption; returns credit."""
        self.load_avg = pelt_update(self.load_avg, running_frac, self._y)
        self.credit = ema_update(self.credit, self.load_avg, self.window_ticks)
        return self.credit


# --- JAX mirror -------------------------------------------------------------


def jax_tick(state, running_frac, window_ticks: int = DEFAULT_EMA_WINDOW,
             halflife: int = PELT_HALFLIFE_TICKS):
    """state = (load_avg, credit) arrays; one functional tick."""
    load_avg, credit = state
    y = 0.5 ** (1.0 / halflife)
    alpha = 2.0 / (window_ticks + 1.0)
    load_avg = y * load_avg + (1.0 - y) * running_frac
    credit = (1.0 - alpha) * credit + alpha * load_avg
    return (load_avg, credit), credit
