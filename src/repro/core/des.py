"""Exact event-driven node simulator — the oracle for ``simkernel``.

Event granularity: arrivals, completions, quantum expiries.  No statistical
burst model (use ``simkernel`` for overhead studies); this engine validates
scheduling ORDER and latency semantics of each policy on small cases:
work conservation, group fairness under CFS, run-to-completion order under
LAGS, RT preemption under LAGS-static.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core import load_credit as lc
from repro.obs.schedstats import SchedStats
from repro.sched import Policy

TICK = lc.TICK_SEC


@dataclass(order=True)
class _Event:
    time: float
    seq: int
    kind: str = field(compare=False)  # arrive | quantum | tick
    payload: tuple = field(compare=False, default=())


@dataclass
class Request:
    fn: int
    arrival: float
    demand: float
    remaining: float
    completion: float = -1.0
    first_run: float = -1.0


class EventSim:
    """Single run queue, ``n_cores`` cores, exact preemptive scheduling."""

    def __init__(self, n_fns: int, n_cores: int, policy: Policy,
                 window: int = 1000):
        self.n_fns = n_fns
        self.n_cores = n_cores
        self.policy = policy
        self.tracker = lc.LoadCreditTracker(n_fns, window_ticks=window)
        self.fn_vrt = np.zeros(n_fns)
        self.requests: List[Request] = []
        self.running: dict = {}  # core -> request idx
        self.runnable: set = set()
        self.now = 0.0
        self._seq = 0
        self.events: list = []
        # schedstats-backed accounting (order switches, run delay, useful
        # seconds per function); the DES models switch cost as zero — it is
        # the ORDER oracle — so switch_s stays 0 here by design.
        self.sched = SchedStats("des")

    @property
    def switches(self) -> int:
        return int(self.sched.switches)

    def submit(self, fn: int, t: float, demand: float):
        i = len(self.requests)
        self.requests.append(Request(fn, t, demand, demand))
        self.sched.account_arrival(fn)
        self._push(t, "arrive", (i,))

    def _push(self, t, kind, payload=()):
        self._seq += 1
        heapq.heappush(self.events, _Event(t, self._seq, kind, payload))

    # --- policy keys on request granularity -------------------------------
    def _key(self, i: int):
        r = self.requests[i]
        return self.policy.request_key(
            self.tracker.credit, self.fn_vrt, r.fn, r.arrival, i
        )

    def _reschedule(self):
        """Assign cores to the |cores| best runnable requests (preemptive)."""
        cand = sorted(self.runnable, key=self._key)
        chosen = cand[: self.n_cores]
        prev = dict(self.running)
        self.running = {}
        used_cores = set()
        # keep requests on their previous cores when still chosen
        for c, i in prev.items():
            if i in chosen:
                self.running[c] = i
                used_cores.add(c)
                chosen.remove(i)
        free = [c for c in range(self.n_cores) if c not in used_cores]
        for c, i in zip(free, chosen):
            self.running[c] = i
            r = self.requests[i]
            if prev.get(c) != i:
                same = prev.get(c) is not None and \
                    self.requests[prev[c]].fn == r.fn
                self.sched.account_switch(r.fn, 0.0, same_group=same)
            if r.first_run < 0:
                r.first_run = self.now
                self.sched.account_run_delay(r.fn, self.now - r.arrival)
        self.sched.sample_runq(self.now, len(self.runnable))

    def _advance(self, dt: float):
        if dt <= 0:
            return
        for c, i in self.running.items():
            r = self.requests[i]
            r.remaining -= dt
            self.fn_vrt[r.fn] += dt
            self.sched.account_useful(r.fn, dt)
        frac = np.zeros(self.n_fns)
        for c, i in self.running.items():
            frac[self.requests[i].fn] += 1.0
        # fractional-tick PELT update
        steps = dt / TICK
        y = lc.pelt_decay() ** steps
        a = 1.0 - (1.0 - 2.0 / (self.tracker.window_ticks + 1.0)) ** steps
        self.tracker.load_avg = y * self.tracker.load_avg + (1 - y) * frac
        self.tracker.credit = (
            (1 - a) * self.tracker.credit + a * self.tracker.load_avg
        )

    def run(self, until: float):
        self._push(until, "end")
        while self.events:
            ev = heapq.heappop(self.events)
            # next completion may occur before the next event
            while True:
                t_next = ev.time
                soonest, who = np.inf, None
                for c, i in self.running.items():
                    t_done = self.now + self.requests[i].remaining
                    if t_done < soonest:
                        soonest, who = t_done, i
                if who is None or soonest > t_next + 1e-12:
                    break
                self._advance(soonest - self.now)
                self.now = soonest
                r = self.requests[who]
                r.remaining = 0.0
                r.completion = self.now
                self.sched.account_completion(r.fn, self.now - r.arrival)
                self.runnable.discard(who)
                self._reschedule()
            self._advance(ev.time - self.now)
            self.now = ev.time
            if ev.kind == "end":
                break
            if ev.kind == "arrive":
                (i,) = ev.payload
                self.runnable.add(i)
                self._reschedule()
            elif ev.kind == "quantum":
                self._reschedule()
            # time-slice rotation whenever the node is oversubscribed
            if len(self.runnable) > self.n_cores:
                self._push(
                    self.now + self.policy.slice_ticks * TICK, "quantum"
                )
        self.sched.account_time(self.now - self.sched.time_s)
        self.sched.capacity_s = self.n_cores * self.now
        self.sched.idle_s = max(
            self.sched.capacity_s - self.sched.useful_s, 0.0
        )
        lat = np.asarray(
            [r.completion - r.arrival for r in self.requests if r.completion >= 0]
        )
        return lat
