"""JAX tick simulator: the paper's scheduler as a composable JAX module.

A functional ``lax.scan`` port of ``simkernel`` supporting CFS and CFS-LAGS.
Fully jit-able, ``vmap``-able over nodes, and pjit-shardable over the
production mesh — the cluster consolidation study runs hundreds of simulated
nodes data-parallel on a pod (see ``repro.core.cluster`` and
``benchmarks/fig7_cluster.py``).

Modelling simplifications vs the numpy engine (validated against it in
``tests/test_simkernel_jax.py``): requests are pre-assigned round-robin to a
fixed per-function slot pool (FIFO within a slot), and core assignment is a
per-tick top-C selection (sticky-core switch accounting is statistical, as in
the numpy engine's burst model).

Policy codes: 0 = CFS (hierarchical vruntime), 1 = CFS-LAGS (Load Credit).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_credit as lc
from repro.core.switch_cost import BASE_US, CROSS_US, PUT_US, SET_US

TICK = lc.TICK_SEC

CFS, LAGS = 0, 1


class SlotTrace(NamedTuple):
    """Per-slot request queues, preassigned (see module docstring)."""

    arrival_tick: jnp.ndarray  # (T, R) int32, padded with BIG
    demand: jnp.ndarray  # (T, R) float32 seconds
    slot_fn: jnp.ndarray  # (T,) int32


class SimParams(NamedTuple):
    n_cores: int
    n_fns: int
    n_ticks: int
    policy: int = CFS
    burst_us: float = 120.0
    depth: float = 2.0
    window_ticks: int = 1000


def _switch_cost_us(same, sib, grp, depth):
    leaf = PUT_US * jnp.log2(1.0 + jnp.maximum(sib, 1.0))
    upper = PUT_US * jnp.log2(1.0 + jnp.maximum(grp, 1.0)) * jnp.maximum(
        depth - 1.0, 1.0
    )
    return BASE_US + leaf + SET_US * depth + jnp.where(same, 0.0, upper + CROSS_US)


def build_slot_trace(workload, n_fns: int, threads_per_fn: int) -> SlotTrace:
    """Pack a ``simkernel.Workload``-style arrival list into fixed slots."""
    BIG = np.iinfo(np.int32).max // 2
    per_slot: list = [[] for _ in range(n_fns * threads_per_fn)]
    for f in range(n_fns):
        arr = workload.arrivals[f]
        dem = workload.service_s[f]
        for j, (t, d) in enumerate(zip(arr, dem)):
            slot = f * threads_per_fn + (j % threads_per_fn)
            per_slot[slot].append((int(t / TICK), float(d)))
    R = max(1, max(len(q) for q in per_slot))
    T = len(per_slot)
    at = np.full((T, R), BIG, np.int32)
    de = np.zeros((T, R), np.float32)
    for s, q in enumerate(per_slot):
        for j, (t, d) in enumerate(q):
            at[s, j] = t
            de[s, j] = d
    slot_fn = np.repeat(np.arange(n_fns, dtype=np.int32), threads_per_fn)
    return SlotTrace(jnp.asarray(at), jnp.asarray(de), jnp.asarray(slot_fn))


@partial(jax.jit, static_argnums=(1,))
def simulate(trace: SlotTrace, p: SimParams):
    """Returns dict of per-request completion ticks + node-level counters."""
    T, R = trace.arrival_tick.shape
    C = p.n_cores

    def tick_body(state, tick):
        ptr, rem, vrt_fn, load, credit, busy, ovh, done_tick = state

        # activate: slot idle (rem<=0, i.e. between requests) whose next
        # request has arrived
        next_arr = jnp.take_along_axis(
            trace.arrival_tick, ptr[:, None], axis=1
        )[:, 0]
        can_start = (rem <= 0.0) & (next_arr <= tick) & (ptr < R)
        new_dem = jnp.take_along_axis(trace.demand, ptr[:, None], axis=1)[:, 0]
        rem = jnp.where(can_start, new_dem, rem)
        runnable = rem > 0.0

        # policy key
        fnv = vrt_fn[trace.slot_fn]
        cred = credit[trace.slot_fn]
        key = jnp.where(p.policy == LAGS, cred, fnv)
        key = jnp.where(runnable, key, jnp.inf)
        # deterministic tie-break by slot id
        key = key + jnp.arange(T) * 1e-12

        # pick C best runnable
        neg, idx = jax.lax.top_k(-key, C)
        picked = jnp.isfinite(-neg)  # (C,)
        run_slots = jnp.where(picked, idx, -1)

        # group stats
        sib_count = jnp.zeros(p.n_fns).at[trace.slot_fn].add(
            runnable.astype(jnp.float32)
        )
        n_grp = jnp.sum(sib_count > 0)
        n_run = jnp.sum(runnable)

        run_fn = trace.slot_fn[jnp.maximum(run_slots, 0)]
        sibs = sib_count[run_fn]
        n_wait = jnp.maximum(n_run - jnp.sum(picked), 0.0)
        p_pre = jnp.minimum(1.0, n_wait / (2.0 * C))

        c_same = _switch_cost_us(True, sibs, n_grp, p.depth)
        c_cross = _switch_cost_us(False, sibs, n_grp, p.depth)
        p_same_cfs = jnp.clip((sibs - 1.0) / jnp.maximum(n_run - 1.0, 1.0), 0, 1)
        cost_cfs = p_same_cfs * c_same + (1 - p_same_cfs) * c_cross

        run_credit = credit[run_fn]
        masked_cred = jnp.where(sib_count > 0, credit, jnp.inf)
        wait_cmin = jnp.min(masked_cred)
        in_order = run_credit <= wait_cmin + 1e-12
        solo = sibs <= 1.0
        cost_lags = jnp.where(in_order & solo, 0.0, jnp.where(in_order, c_same, cost_cfs))
        spb = jnp.where(p.policy == LAGS, 1.0 + 0.85 * p_pre, 1.0 + p_pre)
        cost_v = jnp.where(p.policy == LAGS, cost_lags, cost_cfs) * 1e-6 * spb

        eff = jnp.where(picked, TICK * (cfg_burst := p.burst_us * 1e-6)
                        / (cfg_burst + cost_v), 0.0)
        ovh = ovh + jnp.sum(jnp.where(picked, TICK - eff, 0.0))
        busy = busy + jnp.sum(jnp.minimum(eff, rem[jnp.maximum(run_slots, 0)]
                                          * picked))

        # progress
        dec = jnp.zeros(T).at[jnp.maximum(run_slots, 0)].add(
            eff * picked
        )
        new_rem = rem - dec
        completed = (rem > 0.0) & (new_rem <= 0.0)
        # record completion tick for the slot's current request
        req_idx = jnp.arange(T) * R + jnp.minimum(ptr, R - 1)
        done_flat = done_tick.at[req_idx].set(
            jnp.where(completed, tick, done_tick[req_idx])
        )
        ptr = ptr + completed.astype(jnp.int32)

        # load credit
        frac = jnp.zeros(p.n_fns).at[run_fn].add(
            (eff / TICK) * picked
        )
        (load, credit), _ = lc.jax_tick((load, credit), frac, p.window_ticks)

        # fn vruntime advances by group core-time
        vrt_fn = vrt_fn + jnp.zeros(p.n_fns).at[run_fn].add(eff * picked)

        return (ptr, new_rem, vrt_fn, load, credit, busy, ovh, done_flat), None

    init = (
        jnp.zeros(T, jnp.int32),
        jnp.zeros(T),
        jnp.zeros(p.n_fns),
        jnp.zeros(p.n_fns),
        jnp.zeros(p.n_fns),
        jnp.zeros(()),
        jnp.zeros(()),
        jnp.full((T * R,), -1, jnp.int32),
    )
    state, _ = jax.lax.scan(tick_body, init, jnp.arange(p.n_ticks))
    ptr, rem, vrt_fn, load, credit, busy, ovh, done = state
    return {
        "done_tick": done.reshape(T, R),
        "busy_s": busy,
        "overhead_s": ovh,
        "credit": credit,
    }


def latencies_from(trace: SlotTrace, done_tick) -> np.ndarray:
    """Completed-request latencies in seconds."""
    at = np.asarray(trace.arrival_tick)
    dt = np.asarray(done_tick)
    ok = (dt >= 0) & (at < np.iinfo(np.int32).max // 2)
    return ((dt[ok] + 1) - at[ok]) * TICK
