"""JAX tick simulator: the paper's scheduler as a composable JAX module.

A functional ``lax.scan`` port of ``simkernel``.  Fully jit-able,
``vmap``-able over nodes, and pjit-shardable over the production mesh —
the cluster consolidation study runs hundreds of simulated nodes
data-parallel on a pod (see ``repro.core.cluster`` and
``benchmarks/fig7_cluster.py``).

Policy logic lives entirely in ``repro.sched.jax_backend``: the policy
code in :class:`SimParams` is a static jit argument resolved to pure
``jnp`` key / stickiness / voluntary-cost functions at trace time, so
**every** policy kind — CFS, EEVDF, SCHED_RR, CFS-LAGS, CFS-LAGS-static
and the tuned-slice variants — runs through this one scan body with no
policy branching here.

Modelling simplifications vs the numpy engine (validated against it in
``tests/test_simkernel_jax.py``): requests are pre-assigned round-robin to
a fixed per-function slot pool (FIFO within a slot), core assignment is a
per-tick top-C selection with slice stickiness (sticky-core switch
accounting is statistical, as in the numpy engine's burst model).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import load_credit as lc
from repro.core.switch_cost import BASE_US, CROSS_US, PUT_US, SET_US
from repro.sched import jax_backend as jb

TICK = lc.TICK_SEC

# historical two-policy codes, re-exported for existing callers; the full
# set (EEVDF, RR, LAGS_STATIC, *_TUNED) lives in repro.sched.jax_backend
CFS, LAGS = jb.CFS, jb.LAGS


class SlotTrace(NamedTuple):
    """Per-slot request queues, preassigned (see module docstring)."""

    arrival_tick: jnp.ndarray  # (T, R) int32, padded with BIG
    demand: jnp.ndarray  # (T, R) float32 seconds
    slot_fn: jnp.ndarray  # (T,) int32


class SimParams(NamedTuple):
    n_cores: int
    n_fns: int
    n_ticks: int
    policy: int = CFS  # repro.sched.jax_backend code (static)
    burst_us: float = 120.0
    depth: float = 2.0
    window_ticks: int = 1000
    rt_fns: Tuple[int, ...] = ()  # lags-static: fn ids under SCHED_RR


def _switch_cost_us(same, sib, grp, depth):
    leaf = PUT_US * jnp.log2(1.0 + jnp.maximum(sib, 1.0))
    upper = PUT_US * jnp.log2(1.0 + jnp.maximum(grp, 1.0)) * jnp.maximum(
        depth - 1.0, 1.0
    )
    return BASE_US + leaf + SET_US * depth + jnp.where(same, 0.0, upper + CROSS_US)


def build_slot_trace(workload, n_fns: int, threads_per_fn: int) -> SlotTrace:
    """Pack a ``simkernel.Workload``-style arrival list into fixed slots."""
    BIG = np.iinfo(np.int32).max // 2
    per_slot: list = [[] for _ in range(n_fns * threads_per_fn)]
    for f in range(n_fns):
        arr = workload.arrivals[f]
        dem = workload.service_s[f]
        for j, (t, d) in enumerate(zip(arr, dem)):
            slot = f * threads_per_fn + (j % threads_per_fn)
            per_slot[slot].append((int(t / TICK), float(d)))
    R = max(1, max(len(q) for q in per_slot))
    T = len(per_slot)
    at = np.full((T, R), BIG, np.int32)
    de = np.zeros((T, R), np.float32)
    for s, q in enumerate(per_slot):
        for j, (t, d) in enumerate(q):
            at[s, j] = t
            de[s, j] = d
    slot_fn = np.repeat(np.arange(n_fns, dtype=np.int32), threads_per_fn)
    return SlotTrace(jnp.asarray(at), jnp.asarray(de), jnp.asarray(slot_fn))


@partial(jax.jit, static_argnums=(1,))
def simulate(trace: SlotTrace, p: SimParams):
    """Returns dict of per-request completion ticks + node-level counters."""
    T, R = trace.arrival_tick.shape
    C = p.n_cores
    spec = jb.spec_of(p.policy)
    slice_ticks = spec.slice_ticks
    is_rt_fn = jnp.zeros(p.n_fns, bool)
    if p.rt_fns:
        is_rt_fn = is_rt_fn.at[jnp.asarray(p.rt_fns, jnp.int32)].set(True)

    def tick_body(state, tick):
        (ptr, rem, vrt_fn, load, credit, busy, ovh, done_tick,
         last_pick, slice_left, prev_picked) = state

        # activate: slot idle (rem<=0, i.e. between requests) whose next
        # request has arrived
        next_arr = jnp.take_along_axis(
            trace.arrival_tick, ptr[:, None], axis=1
        )[:, 0]
        can_start = (rem <= 0.0) & (next_arr <= tick) & (ptr < R)
        new_dem = jnp.take_along_axis(trace.demand, ptr[:, None], axis=1)[:, 0]
        rem = jnp.where(can_start, new_dem, rem)
        runnable = rem > 0.0

        # group stats (shared mechanism, not policy)
        sib_count = jnp.zeros(p.n_fns).at[trace.slot_fn].add(
            runnable.astype(jnp.float32)
        )
        fn_runnable = sib_count > 0

        # policy key via the protocol backend; deterministic tie-break by
        # slot id is this backend's secondary
        view = jb.PolicyView(
            ent_group=trace.slot_fn,
            group_vrt=vrt_fn,
            group_credit=credit,
            last_pick_tick=last_pick,
            runnable=runnable,
            group_runnable=fn_runnable,
            is_rt_group=is_rt_fn,
            tick_sec=TICK,
            slice_ticks=slice_ticks,
        )
        key = jb.primary_key(p.policy, view)
        key = jnp.where(runnable, key, jnp.inf)
        key = key + jnp.arange(T) * 1e-12

        # slice stickiness: a slot that holds an unexpired slice keeps its
        # core unless the policy's preemption rule voids it
        continuing = prev_picked & (slice_left > 0) & runnable
        sticky = jb.sticky_mask(p.policy, view, continuing)
        key = jnp.where(sticky, key - 1e18, key)

        # pick C best runnable
        neg, idx = jax.lax.top_k(-key, C)
        picked = jnp.isfinite(-neg)  # (C,)
        run_slots = jnp.where(picked, idx, -1)
        picked_slot = jnp.zeros(T, bool).at[jnp.maximum(run_slots, 0)].set(
            picked
        )

        # slice bookkeeping
        slice_left = jnp.where(
            picked_slot,
            jnp.where(continuing, slice_left - 1, slice_ticks - 1),
            0,
        )
        last_pick = jnp.where(picked_slot, tick.astype(last_pick.dtype),
                              last_pick)

        n_grp = jnp.sum(fn_runnable)
        n_run = jnp.sum(runnable)

        run_fn = trace.slot_fn[jnp.maximum(run_slots, 0)]
        sibs = sib_count[run_fn]
        n_wait = jnp.maximum(n_run - jnp.sum(picked), 0.0)
        p_pre = jnp.minimum(1.0, n_wait / (2.0 * C))

        c_same = _switch_cost_us(True, sibs, n_grp, p.depth)
        c_cross = _switch_cost_us(False, sibs, n_grp, p.depth)
        p_same_cfs = jnp.clip((sibs - 1.0) / jnp.maximum(n_run - 1.0, 1.0), 0, 1)
        cost_cfs = p_same_cfs * c_same + (1 - p_same_cfs) * c_cross

        run_credit = credit[run_fn]
        masked_cred = jnp.where(fn_runnable, credit, jnp.inf)
        wait_cmin = jnp.min(masked_cred)
        cost_us, spb = jb.voluntary_switch(
            p.policy, c_same=c_same, c_cross=c_cross, cost_cfs=cost_cfs,
            run_credit=run_credit, wait_cmin=wait_cmin, sibs=sibs,
            p_preempt=p_pre,
        )
        cost_v = cost_us * 1e-6 * spb

        eff = jnp.where(picked, TICK * (cfg_burst := p.burst_us * 1e-6)
                        / (cfg_burst + cost_v), 0.0)
        ovh = ovh + jnp.sum(jnp.where(picked, TICK - eff, 0.0))
        busy = busy + jnp.sum(jnp.minimum(eff, rem[jnp.maximum(run_slots, 0)]
                                          * picked))

        # progress
        dec = jnp.zeros(T).at[jnp.maximum(run_slots, 0)].add(
            eff * picked
        )
        new_rem = rem - dec
        completed = (rem > 0.0) & (new_rem <= 0.0)
        # record completion tick for the slot's current request
        req_idx = jnp.arange(T) * R + jnp.minimum(ptr, R - 1)
        done_flat = done_tick.at[req_idx].set(
            jnp.where(completed, tick, done_tick[req_idx])
        )
        ptr = ptr + completed.astype(jnp.int32)

        # load credit
        frac = jnp.zeros(p.n_fns).at[run_fn].add(
            (eff / TICK) * picked
        )
        (load, credit), _ = lc.jax_tick((load, credit), frac, p.window_ticks)

        # fn vruntime advances by group core-time
        vrt_fn = vrt_fn + jnp.zeros(p.n_fns).at[run_fn].add(eff * picked)

        return (ptr, new_rem, vrt_fn, load, credit, busy, ovh, done_flat,
                last_pick, slice_left, picked_slot), None

    init = (
        jnp.zeros(T, jnp.int32),
        jnp.zeros(T),
        jnp.zeros(p.n_fns),
        jnp.zeros(p.n_fns),
        jnp.zeros(p.n_fns),
        jnp.zeros(()),
        jnp.zeros(()),
        jnp.full((T * R,), -1, jnp.int32),
        jnp.zeros(T),  # last_pick tick
        jnp.zeros(T, jnp.int32),  # slice_left
        jnp.zeros(T, bool),  # prev_picked
    )
    state, _ = jax.lax.scan(tick_body, init, jnp.arange(p.n_ticks))
    (ptr, rem, vrt_fn, load, credit, busy, ovh, done,
     _last_pick, _slice_left, _prev_picked) = state
    return {
        "done_tick": done.reshape(T, R),
        "busy_s": busy,
        "overhead_s": ovh,
        "credit": credit,
    }


def latencies_from(trace: SlotTrace, done_tick) -> np.ndarray:
    """Completed-request latencies in seconds."""
    at = np.asarray(trace.arrival_tick)
    dt = np.asarray(done_tick)
    ok = (dt >= 0) & (at < np.iinfo(np.int32).max // 2)
    return ((dt[ok] + 1) - at[ok]) * TICK
