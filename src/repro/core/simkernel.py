"""Vectorised tick-level node simulator (numpy fast path).

Models one worker node: ``n_cores`` hardware threads, ``n_fns`` colocated
function cgroups each with a bounded thread pool, per-policy scheduling with
sticky core assignment, wakeup/credit preemption, and the calibrated
context-switch cost model.  One tick = 4 ms (CONFIG_HZ = 250).

This is the engine behind every paper figure (3, 5, 6, 8, 9, 10, 11) and the
cluster consolidation study.  ``des.py`` is the exact event-driven oracle used
to validate it on small cases; ``simkernel_jax.py`` is the jit/vmap/pjit port
used to run hundreds of simulated nodes data-parallel on the pod mesh.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.core import load_credit as lc
from repro.core.switch_cost import switch_cost_us
from repro.sched import Policy
from repro.obs import metrics as obs_metrics
from repro.obs.schedstats import EntityStats, SchedStats

TICK_SEC = lc.TICK_SEC


@dataclass
class Workload:
    """Per-function arrival processes + service demand."""

    n_fns: int
    arrivals: List[np.ndarray]  # per-fn sorted arrival times (sec); open loop
    service_s: List[np.ndarray]  # per-fn per-request CPU demand (sec)
    threads_per_fn: int = 4
    parallelism: int = 1  # threads per request (resctl-parallel: 2)
    closed_loop_slots: int = 0  # >0: resctl-style closed loop, global slots
    duration_s: float = 60.0


@dataclass
class SimConfig:
    n_cores: int = 12
    hierarchy_depth: float = 2.0  # 2 standalone, 5 Knative cluster node
    latency_slo_s: float = 1.0
    seed: int = 0
    model_switch_cost: bool = True
    # Mean CPU-burst length between voluntary switches (block/wake handoffs
    # in the service's thread pools).  100 us reproduces the paper's
    # standalone switch rates; ~280 us the Knative cluster node (§3.2: longer
    # PyTorch bursts, fewer concurrently active functions).
    burst_us: float = 120.0


@dataclass
class SimResult:
    policy: str
    latencies: np.ndarray  # completed-request latencies (sec)
    fn_of: np.ndarray  # function id per completed request (aligned)
    arrival_of: np.ndarray  # arrival time per completed request (aligned)
    n_arrived: int
    n_completed: int
    switches: int
    switch_time_s: float
    busy_time_s: float  # useful work
    duration_s: float
    n_cores: int
    # rich per-fn schedstats (populated only when repro.obs is enabled, so
    # the disabled-telemetry hot path stays unchanged)
    schedstats: Optional[SchedStats] = None

    @property
    def overhead_frac(self) -> float:
        cap = self.n_cores * self.duration_s
        return self.switch_time_s / cap

    @property
    def util_effective(self) -> float:
        return self.busy_time_s / (self.n_cores * self.duration_s)

    @property
    def util_perceived(self) -> float:
        return (self.busy_time_s + self.switch_time_s) / (
            self.n_cores * self.duration_s
        )

    @property
    def mean_switch_cost_us(self) -> float:
        return 1e6 * self.switch_time_s / max(self.switches, 1)

    def throughput_slo(self, slo: float = 1.0) -> float:
        return float(np.sum(self.latencies <= slo)) / self.duration_s

    def pct(self, q: float) -> float:
        return float(np.percentile(self.latencies, q)) if len(self.latencies) else float("nan")

    def sched_summary(self) -> SchedStats:
        """The attached rich schedstats, or a totals-only one derived from
        this result (always available, telemetry on or off)."""
        if self.schedstats is not None:
            return self.schedstats
        from repro.obs.schedstats import from_sim_result

        return from_sim_result(self)


class _State:
    """Mutable simulator state exposed to policies."""

    def __init__(self, wl: Workload, cfg: SimConfig, window: int):
        T = wl.n_fns * wl.threads_per_fn
        self.tick_sec = TICK_SEC
        self.th_fn = np.repeat(np.arange(wl.n_fns), wl.threads_per_fn)
        self.th_state = np.zeros(T, np.int8)  # 0 idle, 1 runnable/running
        self.th_rem = np.zeros(T)
        self.th_req = np.full(T, -1, np.int64)
        self.th_vrt = np.zeros(T)
        self.th_last_run = np.zeros(T)
        self.fn_vrt = np.zeros(wl.n_fns)
        self.core_thread = np.full(cfg.n_cores, -1, np.int64)
        self.core_slice = np.zeros(cfg.n_cores, np.int64)
        self.tracker = lc.LoadCreditTracker(wl.n_fns, window_ticks=window)
        self.credit = self.tracker.credit
        self.now = 0.0
        self.vrt_floor = 0.0

    def runnable_mask(self):
        return self.th_state == 1

    def waiting_mask(self):
        m = self.th_state == 1
        running = self.core_thread[self.core_thread >= 0]
        m[running] = False
        return m


def simulate(
    wl: Workload,
    policy: Policy,
    cfg: SimConfig | None = None,
) -> SimResult:
    cfg = cfg or SimConfig()
    rng = np.random.default_rng(cfg.seed)
    st = _State(wl, cfg, policy.credit_window)
    n_ticks = int(round(wl.duration_s / TICK_SEC))
    C = cfg.n_cores

    # request bookkeeping (grow-able python lists, vector ops per tick)
    req_arrival: list = []
    req_parts: list = []
    req_latency: list = []
    req_fn: list = []
    n_arrived = 0

    # pending per-fn queues + free thread slots
    pending = [deque() for _ in range(wl.n_fns)]
    free_threads = [
        deque(range(f * wl.threads_per_fn, (f + 1) * wl.threads_per_fn))
        for f in range(wl.n_fns)
    ]

    # pre-bucket open-loop arrivals by tick
    arr_tick: dict = {}
    for f in range(wl.n_fns):
        for t_a, s_d in zip(wl.arrivals[f], wl.service_s[f]):
            k = int(t_a / TICK_SEC)
            arr_tick.setdefault(k, []).append((f, t_a, s_d))

    # closed loop: global generator slots, round-robin over functions
    cl_next_fn = 0
    cl_service = (
        np.concatenate(wl.service_s).mean() if wl.closed_loop_slots else 0.1
    )

    # obs instrumentation (per-fn schedstats, switch-cost histogram, runq
    # timeline, run delay).  All per-tick recording is gated on ``obs_on``
    # captured once here, so disabled telemetry adds no hot-loop work.
    obs_on = obs_metrics.enabled()
    sched: Optional[SchedStats] = None
    if obs_on:
        sched = SchedStats(f"simkernel.{policy.name}")
        fn_busy = np.zeros(wl.n_fns)
        fn_switches = np.zeros(wl.n_fns)
        fn_switch_time = np.zeros(wl.n_fns)
        th_wait_start = np.full(wl.n_fns * wl.threads_per_fn, -1.0)

    def submit(f: int, t_a: float, demand: float) -> None:
        nonlocal n_arrived
        rid = len(req_arrival)
        req_arrival.append(t_a)
        req_parts.append(wl.parallelism)
        req_latency.append(-1.0)
        req_fn.append(f)
        n_arrived += 1
        per = demand / wl.parallelism
        for _ in range(wl.parallelism):
            if free_threads[f]:
                th = free_threads[f].popleft()
                st.th_state[th] = 1
                st.th_rem[th] = per
                st.th_req[th] = rid
                if obs_on:
                    th_wait_start[th] = t_a  # runnable from arrival
                # CFS wakeup placement: a waking group's vruntime is clamped
                # to (min runnable group vrt - sched_latency) so long-idle
                # groups run soon but cannot monopolise with ancient lag.
                st.fn_vrt[f] = max(st.fn_vrt[f], st.vrt_floor - 0.024)
            else:
                pending[f].append((rid, per))

    switches = 0
    switch_time = 0.0
    busy_time = 0.0

    if wl.closed_loop_slots:
        for s in range(wl.closed_loop_slots):
            f = cl_next_fn
            cl_next_fn = (cl_next_fn + 1) % wl.n_fns
            d = float(wl.service_s[f % wl.n_fns][s % len(wl.service_s[f % wl.n_fns])])
            submit(f, 0.0, d)

    for tick in range(n_ticks):
        st.now = tick * TICK_SEC
        runnable0 = st.runnable_mask()
        if runnable0.any():
            st.vrt_floor = float(st.fn_vrt[np.unique(st.th_fn[runnable0])].min())
        # 1. arrivals
        for (f, t_a, s_d) in arr_tick.get(tick, ()):  # open loop
            submit(f, t_a, s_d)

        # 2. release cores: completed/idle threads, expired slices, preemption
        for c in range(C):
            th = st.core_thread[c]
            if th >= 0 and st.th_state[th] != 1:
                st.core_thread[c] = -1
        st.core_slice = np.maximum(st.core_slice - 1, 0)
        expired = (st.core_thread >= 0) & (st.core_slice == 0)
        # expired threads go back to the pool (may be re-picked immediately)
        for c in np.where(expired)[0]:
            st.core_thread[c] = -1
        if st.waiting_mask().any():
            for c in policy.preempt_cores(st):
                st.core_thread[c] = -1

        # 3. fill free cores in policy-key order
        free_cores = np.where(st.core_thread < 0)[0]
        if len(free_cores):
            wait = st.waiting_mask()
            n_waiting = int(wait.sum())
            if n_waiting:
                keys = policy.keys(st)
                cand = np.where(wait)[0]
                cand = cand[np.argsort(keys[cand], kind="stable")]
                take = list(cand[: len(free_cores)])
                # prefer re-assigning a thread to the core it last ran on:
                # a re-picked leftmost task is NOT a context switch in CFS.
                prev = getattr(st, "_prev_assign", None)
                assigned = {}
                if prev is not None:
                    take_set = set(take)
                    for c in free_cores:
                        if prev[c] in take_set:
                            assigned[c] = prev[c]
                            take_set.discard(prev[c])
                    take = [t for t in take if t in take_set]
                rest = [c for c in free_cores if c not in assigned]
                for c, th in list(assigned.items()) + list(zip(rest, take)):
                    st.core_thread[c] = th
                    st.core_slice[c] = policy.slice_ticks
                    st.th_last_run[th] = st.now
                    if obs_on and th_wait_start[th] >= 0:
                        sched.account_run_delay(
                            int(st.th_fn[th]),
                            max(st.now - th_wait_start[th], 0.0),
                        )
                        th_wait_start[th] = -1.0

        # 4. progress running threads, charge switch costs
        running = st.core_thread >= 0
        eff = np.full(C, TICK_SEC)
        runnable = st.runnable_mask()
        sib_count = np.bincount(st.th_fn[runnable], minlength=wl.n_fns)
        n_groups_runnable = max(int((sib_count > 0).sum()), 1)
        n_runnable = max(int(runnable.sum()), 1)

        # 4a. involuntary: core's thread changed since last tick (slice
        # expiry, wakeup/credit preemption, load balancing)
        if not hasattr(st, "_prev_assign"):
            st._prev_assign = np.full(C, -2, np.int64)
            st._prev_fn = np.full(C, -2, np.int64)
        changed = running & (st.core_thread != st._prev_assign)
        if cfg.model_switch_cost and changed.any():
            new_fn = np.where(running, st.th_fn[np.maximum(st.core_thread, 0)], -1)
            same = (new_fn == st._prev_fn) & (st._prev_fn >= 0)
            sibs = sib_count[np.maximum(new_fn, 0)]
            cost_us = switch_cost_us(
                same[changed],
                siblings=sibs[changed],
                groups=n_groups_runnable,
                depth=cfg.hierarchy_depth,
            )
            cost_s = np.minimum(cost_us * 1e-6, TICK_SEC)
            eff[changed] -= cost_s
            switches += int(changed.sum())
            switch_time += float(cost_s.sum())
            if obs_on:
                ch_fn = new_fn[changed]
                np.add.at(fn_switches, ch_fn, 1.0)
                np.add.at(fn_switch_time, ch_fn, cost_s)
                sched.switch_cost_us.record_many(cost_s * 1e6)
        st._prev_assign = st.core_thread.copy()
        st._prev_fn = np.where(
            running, st.th_fn[np.maximum(st.core_thread, 0)], -1
        )

        # 4b. voluntary: block/wake handoffs every ~burst_us of CPU time.
        # In steady state a core alternates burst + schedule(): useful
        # fraction = burst/(burst + spb*cost) where spb (switches-per-burst)
        # also accounts for wakeup-preemption storms: at high contention a
        # woken task usually preempts another core, doubling the effective
        # switch rate (this is the paper's "rate" growth term, Fig 10).
        # The per-policy handoff cost (vruntime-ordered picks vs LAGS
        # run-to-completion) lives in the policy protocol —
        # ``repro.sched.numpy_backend.Policy.voluntary_switch``; this engine
        # only supplies the calibrated same/cross-cgroup cost samples.
        if cfg.model_switch_cost and running.any():
            burst_s = cfg.burst_us * 1e-6
            run_th_all = st.core_thread[running]
            run_fn = st.th_fn[run_th_all]
            sibs = sib_count[run_fn].astype(np.float64)
            n_waiting = max(n_runnable - int(running.sum()), 0)
            p_preempt = min(1.0, n_waiting / (2.0 * C))
            c_same = switch_cost_us(
                True, siblings=sibs, groups=n_groups_runnable,
                depth=cfg.hierarchy_depth,
            )
            c_cross = switch_cost_us(
                False, siblings=sibs, groups=n_groups_runnable,
                depth=cfg.hierarchy_depth,
            )
            p_same_cfs = np.clip((sibs - 1.0) / max(n_runnable - 1.0, 1.0), 0, 1)
            cost_cfs = p_same_cfs * c_same + (1.0 - p_same_cfs) * c_cross
            cost_v, spb = policy.voluntary_switch(
                st, run_fn, sibs, c_same, c_cross, cost_cfs, p_preempt
            )
            cost_v_s = cost_v * 1e-6 * spb
            frac_ovh = cost_v_s / (burst_s + cost_v_s)
            e = eff[running]
            v_ovh = e * frac_ovh
            n_sw = e / (burst_s + cost_v_s) * spb * (cost_v_s > 0)
            eff[running] = e - v_ovh
            switches += int(np.round(n_sw.sum()))
            switch_time += float(v_ovh.sum())
            if obs_on:
                np.add.at(fn_switches, run_fn, n_sw)
                np.add.at(fn_switch_time, run_fn, v_ovh)
                # per-switch cost, weighted by this core's switch count
                for i in np.where(n_sw > 0)[0]:
                    sched.switch_cost_us.record(
                        1e6 * v_ovh[i] / n_sw[i], weight=float(n_sw[i])
                    )

        run_th = st.core_thread[running]
        eff_run = eff[running]
        work = np.minimum(st.th_rem[run_th], eff_run)
        busy_time += float(work.sum())
        if obs_on:
            np.add.at(fn_busy, st.th_fn[run_th], work)
            sched.sample_runq(st.now, n_runnable)
        st.th_rem[run_th] -= eff_run
        st.th_vrt[run_th] += eff_run
        np.add.at(st.fn_vrt, st.th_fn[run_th], eff_run)

        # 5. completions
        done = run_th[st.th_rem[run_th] <= 0.0]
        for th in done:
            rid = int(st.th_req[th])
            f = int(st.th_fn[th])
            req_parts[rid] -= 1
            if req_parts[rid] == 0:
                req_latency[rid] = (st.now + TICK_SEC) - req_arrival[rid]
                if wl.closed_loop_slots:  # closed loop: next request now
                    f2 = cl_next_fn
                    cl_next_fn = (cl_next_fn + 1) % wl.n_fns
                    d = float(
                        wl.service_s[f2][rid % len(wl.service_s[f2])]
                    )
                    submit(f2, st.now + TICK_SEC, d)
            st.th_state[th] = 0
            st.th_req[th] = -1
            if pending[f]:
                rid2, per = pending[f].popleft()
                st.th_state[th] = 1
                st.th_rem[th] = per
                st.th_req[th] = rid2
                st.th_vrt[th] = max(st.th_vrt[th], st.fn_vrt[f])
                if obs_on:
                    th_wait_start[th] = st.now  # runnable from slot pickup
            else:
                free_threads[f].append(th)

        # 6. load-credit tick: per-fn share of core time this tick
        run_frac = np.zeros(wl.n_fns)
        np.add.at(run_frac, st.th_fn[run_th], eff_run / TICK_SEC)
        st.credit = st.tracker.tick(run_frac)

    done_idx = [i for i, l in enumerate(req_latency) if l >= 0.0]
    lat = np.asarray([req_latency[i] for i in done_idx])
    if obs_on:
        sched.time_s = wl.duration_s
        sched.capacity_s = C * wl.duration_s
        sched.useful_s = busy_time
        sched.switch_s = switch_time
        sched.switches = float(switches)
        sched.idle_s = max(sched.capacity_s - busy_time - switch_time, 0.0)
        sched.latency.record_many(lat)
        arrived_per_fn = np.bincount(
            np.asarray(req_fn, np.int64), minlength=wl.n_fns
        ) if req_fn else np.zeros(wl.n_fns, np.int64)
        done_per_fn = np.bincount(
            np.asarray([req_fn[i] for i in done_idx], np.int64),
            minlength=wl.n_fns,
        ) if done_idx else np.zeros(wl.n_fns, np.int64)
        for f in range(wl.n_fns):
            e = sched.entities.get(f)
            if e is None:
                e = sched.entities[f] = EntityStats()
            e.useful_s = float(fn_busy[f])
            e.switch_s = float(fn_switch_time[f])
            e.switches = float(fn_switches[f])
            e.arrived = int(arrived_per_fn[f])
            e.completed = int(done_per_fn[f])
    return SimResult(
        policy=policy.name,
        latencies=lat,
        fn_of=np.asarray([req_fn[i] for i in done_idx], np.int64),
        arrival_of=np.asarray([req_arrival[i] for i in done_idx]),
        n_arrived=n_arrived,
        n_completed=len(lat),
        switches=switches,
        switch_time_s=switch_time,
        busy_time_s=busy_time,
        duration_s=wl.duration_s,
        n_cores=C,
        schedstats=sched,
    )
