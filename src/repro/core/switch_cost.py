"""Context-switch cost model, calibrated to the paper's ftrace measurements.

The paper (§3) finds the cost of one ``schedule()`` call is dominated not by
``pick_next_entity`` (left-most rb-tree node, cheap) but by re-inserting the
preempted entity *and its ancestors*: one ``put_prev_entity`` per cgroup
hierarchy level.  Each re-insert is an O(log |cfs_rq|) rb-tree insert into
that level's queue: the task into its group's rq (|rq| = runnable siblings)
and — when the switch crosses cgroups — the group entities into the parent
rqs (|rq| = runnable groups), repeated for ``depth-1`` upper levels.  A
same-group switch touches only the leaf rq, which is the paper's observation
that "overhead becomes increasingly significant as context switching occurs
between tasks that are not siblings within the same cgroup".

  cost_us = BASE
          + PUT * log2(1 + siblings)                       (leaf re-insert)
          + [cross] * ( PUT * log2(1 + groups) * (depth-1)  (ancestor chain)
                        + CROSS )                           (metric updates)
          + SET * depth                                     (set_next walk)

Calibration targets (asserted by tests/test_switch_cost.py):
  * standalone (depth 2), low colocation, short queues:      <  10 us  (Fig 3c)
  * standalone, density 19x (228 fns, cross-group):          ~  20 us  (Fig 3c)
  * CFS at high colocation (mixed):                          ~  21 us  (Fig 10)
  * LAGS at high colocation (mostly sibling switches):       ~  13 us  (Fig 10)
  * Knative cluster node (depth 5, 100 busy pods):           ~  48 us  (§3.2)
"""
from __future__ import annotations

import numpy as np

BASE_US = 0.5
PUT_US = 1.55  # per log2(1+rq_len) rb-tree re-insert
SET_US = 0.35  # set_next_entity, per hierarchy level
CROSS_US = 1.0  # cgroup-crossing bookkeeping (load/metric updates)


def switch_cost_us(same_group, siblings=1.0, groups=2.0, depth: float = 2.0):
    """Vectorised cost of one context switch in microseconds.

    same_group: next task shares the cgroup of the previous task.
    siblings:   runnable threads in the previous task's cgroup (leaf rq len).
    groups:     runnable cgroups on the node (upper rq len).
    depth:      cgroup hierarchy depth (2 = faas.slice/func-N standalone
                microbenchmark; 5 = kubepods/burstable/pod/container Knative).
    """
    same = np.asarray(same_group, bool)
    sib = np.maximum(np.asarray(siblings, np.float64), 1.0)
    grp = np.maximum(np.asarray(groups, np.float64), 1.0)
    leaf = PUT_US * np.log2(1.0 + sib)
    upper = PUT_US * np.log2(1.0 + grp) * np.maximum(depth - 1.0, 1.0)
    cost = BASE_US + leaf + SET_US * depth + np.where(same, 0.0, upper + CROSS_US)
    return cost


def calibration_table():
    """Reference points used by tests (see docstring for provenance)."""
    return {
        "standalone_low_density": float(
            switch_cost_us(False, siblings=2, groups=4, depth=2)
        ),
        "standalone_density19_cross": float(
            switch_cost_us(False, siblings=4, groups=228, depth=2)
        ),
        "standalone_density19_same": float(
            switch_cost_us(True, siblings=4, groups=228, depth=2)
        ),
        "cluster_100pods_cross": float(
            switch_cost_us(False, siblings=8, groups=100, depth=5)
        ),
    }
