"""Azure-Functions-style trace synthesis (paper §3, Fig 2).

The paper segments the Azure Functions Invocation Trace into 5-minute
windows, keeps the busiest segment per function, sorts the per-function
request rates, and splits them into 10 equal-size *demand bands* (heavily
skewed: tens of req/s for most functions, thousands for the busiest).
Colocation benchmarks draw functions equally from each band and scale the
count as ``density x n_cores``.

We synthesise the same structure: band rates follow a log-spaced heavy tail
calibrated so that at the paper's peak-throughput density (9x on 12 HT with
~100 ms mean execution) aggregate demand matches node capacity.  Workloads:

  * ``azure2021`` — bursty arrivals: per-function on/off (Markov-modulated
    Poisson) with rate drawn from the function's band.
  * ``resctl``    — closed-loop constant load (self-tuning concurrency).
  * ``random``    — worst case: every function uniform 0-5 req/s, aggregate
    peak matched to azure2021.
  * ``resctl-parallel`` — each invocation = 2 worker threads, both must
    finish (fig 11b).
  * ``resctl-mix`` — Alibaba mix: 30% 10 ms, 40% 100 ms, 30% 1000 ms (fig 11c).
"""
from __future__ import annotations

import numpy as np

from repro.core.simkernel import Workload

N_BANDS = 10
MEAN_EXEC_S = 0.100  # Fibonacci microbenchmark calibrated to ~100 ms
PEAK_DENSITY = 9  # paper: azure2021 peak throughput at 9x on 12 HT


def band_rates(n_cores: int = 12, mean_exec_s: float = MEAN_EXEC_S) -> np.ndarray:
    """Per-band mean request rate (req/s), heavy-tailed across 10 bands.

    Calibrated so that ``PEAK_DENSITY * n_cores`` functions drawn equally
    from all bands offer ~100% of node CPU capacity.
    """
    raw = np.logspace(0.0, 2.6, N_BANDS)  # 1 .. ~400 relative (heavier tail)
    # Mean aggregate demand at the 9x peak sits well below raw capacity: the
    # trace is bursty (ON/OFF duty ~0.16), so the node saturates during burst
    # overlaps while mean load is ~55% — matching the paper's Fig 3 shape
    # (peak at 9x, graceful 35% CFS degradation at 19x rather than collapse).
    capacity_rps = 0.60 * n_cores / mean_exec_s
    n_fns = PEAK_DENSITY * n_cores
    per_band = n_fns / N_BANDS
    total_raw = per_band * raw.sum()
    return raw * (capacity_rps / total_raw)


def fn_rates(n_fns: int, n_cores: int = 12, seed: int = 0) -> np.ndarray:
    """Assign each function a rate by drawing equally from each band."""
    rng = np.random.default_rng(seed)
    bands = band_rates(n_cores)
    rates = np.empty(n_fns)
    for i in range(n_fns):
        b = i % N_BANDS
        rates[i] = bands[b] * rng.uniform(0.6, 1.4)
    return rates


def _mmpp_arrivals(rate, duration, rng, burst_on=1.5, burst_off=10.0):
    """Markov-modulated Poisson: ON (bursty) / OFF periods, mean ``rate``."""
    if rate <= 0:
        return np.empty(0)
    frac_on = burst_on / (burst_on + burst_off)
    on_rate = rate / frac_on
    out = []
    t = 0.0
    on = rng.uniform() < frac_on
    while t < duration:
        seg = rng.exponential(burst_on if on else burst_off)
        seg = min(seg, duration - t)
        if on and on_rate > 0:
            n = rng.poisson(on_rate * seg)
            out.append(t + np.sort(rng.uniform(0, seg, n)))
        t += seg
        on = not on
    return np.concatenate(out) if out else np.empty(0)


def make_workload(
    kind: str,
    n_fns: int,
    duration_s: float = 60.0,
    n_cores: int = 12,
    seed: int = 0,
    threads_per_fn: int = 0,
    exec_s: float = MEAN_EXEC_S,
    rates: np.ndarray = None,
    fn_ids: np.ndarray = None,
    extra: np.ndarray = None,
) -> Workload:
    """Synthesise a workload; see the module docstring.

    ``rates`` (azure2021 only) overrides the band-model draw with explicit
    per-function request rates — used by the fleet chaos layer, where a
    node's offered load must follow the *actual functions assigned to it*
    (regenerating by count alone loses the heavy-band demand mass of
    migrated functions).

    ``fn_ids`` (with ``rates``) draws each function's arrival stream from
    its own generator keyed on ``(seed, global fn id)`` instead of one
    shared stream.  These are common random numbers across placements: a
    function keeps the *same* arrival realization no matter which node it
    sits on, so comparing a rebalanced fleet against a fault-free
    reference measures failover cost, not workload-redraw noise.

    ``extra`` (with ``rates``) adds exactly ``extra[f]`` additional
    arrivals per function, spread uniformly over the window.  This is the
    replay channel for *known pending requests* (a failover retry backlog,
    work carried over an epoch boundary): feeding a backlog through the
    MMPP as added rate would realize with burst-modulated variance — a
    replayed backlog could draw several times its mass, or almost none —
    so replays inject by count, not by rate.
    """
    rng = np.random.default_rng(seed)
    if rates is not None and kind != "azure2021":
        raise ValueError("explicit rates are only supported for azure2021")
    if fn_ids is not None and rates is None:
        raise ValueError("fn_ids requires explicit rates")
    if extra is not None and rates is None:
        raise ValueError("extra arrivals require explicit rates")
    arrivals, service = [], []
    # Open-loop serverless functions spawn a handler thread per invocation
    # (paper §3: unlike resctl, azure2021 does not limit contending threads —
    # every arrival contends in the run queues immediately); closed-loop
    # resctl needs only a small pool.
    if threads_per_fn <= 0:
        threads_per_fn = 4 if kind.startswith("resctl") else 192

    if kind == "azure2021":
        if rates is None:
            rates = fn_rates(n_fns, n_cores, seed)
        else:
            rates = np.asarray(rates, float)
            assert rates.shape == (n_fns,), (
                f"rates must have one entry per function: "
                f"{rates.shape} != ({n_fns},)")
        if fn_ids is not None:
            fn_ids = np.asarray(fn_ids, np.int64)
            assert fn_ids.shape == (n_fns,), (
                f"fn_ids must have one entry per function: "
                f"{fn_ids.shape} != ({n_fns},)")
        if extra is not None:
            extra = np.asarray(extra, np.int64)
            assert extra.shape == (n_fns,), (
                f"extra must have one entry per function: "
                f"{extra.shape} != ({n_fns},)")
        for f in range(n_fns):
            rf = (rng if fn_ids is None
                  else np.random.default_rng((seed, int(fn_ids[f]))))
            a = _mmpp_arrivals(rates[f], duration_s, rf)
            if extra is not None and extra[f] > 0:
                replay = rf.uniform(0.0, duration_s, int(extra[f]))
                a = np.sort(np.concatenate([a, replay]))
            arrivals.append(a)
            service.append(np.full(len(a), exec_s))
        return Workload(n_fns, arrivals, service, threads_per_fn, duration_s=duration_s)

    if kind == "random":
        # worst case: uniform 0-5 req/s; aggregate peak matched to azure2021
        az_total = fn_rates(n_fns, n_cores, seed).sum()
        raw = rng.uniform(0.0, 5.0, n_fns)
        rates = raw * (az_total / max(raw.sum(), 1e-9))
        for f in range(n_fns):
            n = rng.poisson(rates[f] * duration_s)
            a = np.sort(rng.uniform(0, duration_s, n))
            arrivals.append(a)
            service.append(np.full(len(a), MEAN_EXEC_S))
        return Workload(n_fns, arrivals, service, threads_per_fn, duration_s=duration_s)

    if kind in ("resctl", "resctl-parallel", "resctl-mix"):
        par = 2 if kind == "resctl-parallel" else 1
        if kind == "resctl-mix":
            # Alibaba: 30% 10ms, 40% 100ms, 30% 1000ms
            svc = rng.choice([0.010, 0.100, 1.000], size=512, p=[0.3, 0.4, 0.3])
        else:
            svc = np.full(512, MEAN_EXEC_S)
        for f in range(n_fns):
            arrivals.append(np.empty(0))
            service.append(svc.copy())
        return Workload(
            n_fns,
            arrivals,
            service,
            threads_per_fn,
            parallelism=par,
            closed_loop_slots=(3 * n_cores) // 2,
            duration_s=duration_s,
        )

    raise ValueError(f"unknown workload kind {kind!r}")


def demand_band_of(n_fns: int) -> np.ndarray:
    """Band index per function (0 = lightest), matching ``fn_rates`` layout."""
    return np.arange(n_fns) % N_BANDS


def lightest_band_fns(n_fns: int, n_bands_low: int = 2) -> np.ndarray:
    """Function ids in the lowest demand bands (for CFS-LAGS-static)."""
    band = demand_band_of(n_fns)
    return np.where(band < n_bands_low)[0]
