"""Back-compat shim: the policy core moved to ``repro.sched``.

CFS, EEVDF, SCHED_RR, CFS-LAGS and CFS-LAGS-static are defined once in
the unified scheduling package — ``repro.sched.protocol`` for the spec
registry and shared preemption rule, ``repro.sched.numpy_backend`` for
the float64 reference backend this module used to implement.  Import
from ``repro.sched`` in new code; this module only preserves the old
import path for existing consumers.
"""
from __future__ import annotations

from repro.sched.numpy_backend import (  # noqa: F401
    CFS_DEFAULT_SLICE_TICKS,
    TUNED_SLICE_TICKS,
    Policy,
    make_policy,
)

__all__ = [
    "CFS_DEFAULT_SLICE_TICKS", "TUNED_SLICE_TICKS", "Policy", "make_policy",
]
