"""Scheduling policies: CFS, EEVDF, SCHED_RR, CFS-LAGS, CFS-LAGS-static.

Each policy supplies:
  * ``keys(state)``      — per-thread priority key tuple (lexicographic, lower
                           is first) used to fill free cores;
  * ``slice_ticks``      — how long an assigned thread keeps its core;
  * ``preempt(state)``   — cores to release early this tick (wakeup
                           preemption / credit preemption / RT preemption).

The simulator (``simkernel``) owns the state arrays; policies are pure key
producers so the same logic drives the numpy engine, the lax.scan engine and
the serving-engine admission scheduler.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

# scheduler tick = 4 ms (CONFIG_HZ = 250)
CFS_DEFAULT_SLICE_TICKS = 1  # min_granularity ~3 ms -> 1 tick under load
TUNED_SLICE_TICKS = 25  # 100 ms (fig 11 "tuned" baselines / SCHED_RR quantum)


@dataclass
class Policy:
    name: str = "cfs"
    slice_ticks: int = CFS_DEFAULT_SLICE_TICKS
    # LAGS
    lags: bool = False
    credit_window: int = 1000
    # EEVDF
    eevdf: bool = False
    # RR (soft real-time round robin across all functions)
    rr: bool = False
    # LAGS-static: set of fn ids under SCHED_RR priority
    static_rt_fns: Optional[np.ndarray] = None

    def keys(self, st) -> np.ndarray:
        """Return a (T,) float64 composite key; lower runs first.

        Built as primary * 1e9 + secondary-rank so a single argsort suffices.
        """
        T = st.th_fn.shape[0]
        # secondary: thread vruntime rank in [0, 1)
        order = np.argsort(st.th_vrt, kind="stable")
        rank = np.empty(T)
        rank[order] = np.arange(T) / max(T, 1)

        if self.static_rt_fns is not None:
            is_rt = np.isin(st.th_fn, self.static_rt_fns)
            # RT: FIFO by last-run (round robin); CFS others by (vrt_g, vrt_t)
            base = np.where(is_rt, -1e12 + st.th_last_run, st.fn_vrt[st.th_fn] * 1e9)
            return base + rank
        if self.rr:
            return st.th_last_run * 1e9 + rank
        if self.lags:
            return st.credit[st.th_fn] * 1e9 + rank
        if self.eevdf:
            # eligible (lag >= 0) first, then earliest virtual deadline
            v = st.fn_vrt[st.th_fn]
            vmean = (
                np.mean(st.fn_vrt[np.unique(st.th_fn[st.runnable_mask()])])
                if st.runnable_mask().any()
                else 0.0
            )
            deadline = v + self.slice_ticks * st.tick_sec
            inel = (v > vmean + 1e-12).astype(np.float64)
            return inel * 1e15 + deadline * 1e9 + rank
        # CFS: hierarchical (group vruntime, thread vruntime)
        return st.fn_vrt[st.th_fn] * 1e9 + rank

    def preempt_cores(self, st) -> np.ndarray:
        """Indices of cores to release for a waiting lower-key thread."""
        running = st.core_thread >= 0
        if not running.any():
            return np.empty(0, np.int64)
        wait_mask = st.waiting_mask()
        if not wait_mask.any():
            return np.empty(0, np.int64)
        if self.lags:
            # paper §4.3 global path: a waking task of a lower-credit cgroup
            # takes any core running a higher-credit task.
            wait_credit = st.credit[st.th_fn[wait_mask]].min()
            run_credit = np.where(
                running, st.credit[st.th_fn[np.maximum(st.core_thread, 0)]], -np.inf
            )
            worst = int(np.argmax(run_credit))
            if wait_credit + 1e-12 < run_credit[worst]:
                return np.asarray([worst])
            return np.empty(0, np.int64)
        if self.static_rt_fns is not None:
            # RT tasks preempt CFS tasks immediately
            rt_waiting = np.isin(st.th_fn[wait_mask], self.static_rt_fns).any()
            if rt_waiting:
                run_is_cfs = running & ~np.isin(
                    st.th_fn[np.maximum(st.core_thread, 0)], self.static_rt_fns
                )
                idx = np.where(run_is_cfs)[0]
                return idx[:1]
            return np.empty(0, np.int64)
        # CFS / EEVDF wakeup preemption: waiting group vrt far behind running
        gran = st.tick_sec  # wakeup_granularity ~ one tick
        wait_v = st.fn_vrt[st.th_fn[wait_mask]].min()
        run_v = np.where(
            running, st.fn_vrt[st.th_fn[np.maximum(st.core_thread, 0)]], -np.inf
        )
        worst = int(np.argmax(run_v))
        if wait_v + gran < run_v[worst]:
            return np.asarray([worst])
        return np.empty(0, np.int64)


def make_policy(name: str, **kw) -> Policy:
    name = name.lower()
    if name == "cfs":
        return Policy(name="cfs", **kw)
    if name == "cfs-tuned":
        return Policy(name="cfs-tuned", slice_ticks=TUNED_SLICE_TICKS, **kw)
    if name == "eevdf":
        return Policy(name="eevdf", eevdf=True, **kw)
    if name == "eevdf-tuned":
        return Policy(
            name="eevdf-tuned", eevdf=True, slice_ticks=TUNED_SLICE_TICKS, **kw
        )
    if name == "rr":
        return Policy(name="rr", rr=True, slice_ticks=TUNED_SLICE_TICKS, **kw)
    if name == "lags":
        return Policy(name="lags", lags=True, **kw)
    if name == "lags-static":
        return Policy(
            name="lags-static", slice_ticks=TUNED_SLICE_TICKS, **kw
        )
    raise ValueError(f"unknown policy {name!r}")
