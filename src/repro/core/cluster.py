"""Cluster consolidation study (paper §5.1, Fig 7).

A cluster of identical 12-HT worker nodes hosts ~800 function containers
(Azure-2019 downscaled).  Baseline static reservation needs ``base_nodes``
nodes to meet peak demand; we consolidate the same workload onto fewer nodes
and find the smallest count per policy that preserves the SLO.  Nodes are
statistically identical under banded round-robin placement, so one node is
simulated per (n_nodes, policy) configuration and scaled — the same
approximation is exercised against the multi-node exact path in tests.

The paper's headline: CFS needs 14 nodes; CFS-LAGS holds the same latency
distribution on 10 (-28 %), raising safe utilisation from ~45 % to ~55 %.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core.policies import make_policy
from repro.core.simkernel import SimConfig, SimResult, simulate
from repro.core.traces import make_workload


@dataclass
class ClusterResult:
    policy: str
    n_nodes: int
    p50: float
    p95: float
    thr_slo: float
    util_effective: float
    util_perceived: float
    overhead_frac: float


def simulate_node_share(
    policy_name: str,
    total_fns: int,
    n_nodes: int,
    duration_s: float = 30.0,
    n_cores: int = 12,
    seed: int = 7,
) -> SimResult:
    """Simulate one representative node holding its share of the cluster."""
    fns_per_node = max(1, total_fns // n_nodes)
    wl = make_workload(
        "azure2021", fns_per_node, duration_s=duration_s, n_cores=n_cores,
        seed=seed, exec_s=0.2,  # cluster-mode PyTorch-class requests (§3.2)
    )
    return simulate(
        wl, make_policy(policy_name),
        SimConfig(n_cores=n_cores, hierarchy_depth=5.0, burst_us=280.0,
                  seed=seed),
    )


def simulate_node_share_jax(
    policy_name: str,
    total_fns: int,
    n_nodes: int,
    duration_s: float = 30.0,
    n_cores: int = 12,
    seed: int = 7,
    threads_per_fn: int = 8,
) -> SimResult:
    """One representative node on the ``lax.scan`` backend.

    Same share split as :func:`simulate_node_share`, but through
    ``repro.core.simkernel_jax`` — any registered policy, jit-compiled,
    ``vmap``-able across the (n_nodes, policy) grid on an accelerator.
    Returned as a :class:`SimResult` so the SLO search is backend-blind
    (the scan backend folds switch time into ``overhead_s``; discrete
    switch counts stay numpy-only).
    """
    from repro.core import simkernel_jax as sj
    from repro.sched.jax_backend import CODE_OF

    fns_per_node = max(1, total_fns // n_nodes)
    wl = make_workload(
        "azure2021", fns_per_node, duration_s=duration_s, n_cores=n_cores,
        seed=seed, exec_s=0.2, threads_per_fn=threads_per_fn,
    )
    trace = sj.build_slot_trace(wl, fns_per_node, threads_per_fn)
    p = sj.SimParams(
        n_cores=n_cores, n_fns=fns_per_node,
        n_ticks=int(duration_s / sj.TICK), policy=CODE_OF[policy_name],
        burst_us=280.0, depth=5.0,
    )
    out = sj.simulate(trace, p)
    lat = sj.latencies_from(trace, out["done_tick"])
    at = np.asarray(trace.arrival_tick)
    dt = np.asarray(out["done_tick"])
    ok = (dt >= 0) & (at < np.iinfo(np.int32).max // 2)
    fn_of = np.broadcast_to(
        np.asarray(trace.slot_fn)[:, None], at.shape
    )[ok]
    n_arrived = int((at < np.iinfo(np.int32).max // 2).sum())
    return SimResult(
        policy=policy_name,
        latencies=lat,
        fn_of=fn_of,
        arrival_of=at[ok] * sj.TICK,
        n_arrived=n_arrived,
        n_completed=len(lat),
        switches=0,
        switch_time_s=float(out["overhead_s"]),
        busy_time_s=float(out["busy_s"]),
        duration_s=duration_s,
        n_cores=n_cores,
    )


def consolidation_sweep(
    total_fns: int = 800,
    node_counts=(15, 14, 12, 11, 10, 9, 8),
    policies=("cfs", "lags"),
    duration_s: float = 30.0,
    slo_s: float = 1.0,
    backend: str = "numpy",
) -> List[ClusterResult]:
    node_share = (
        simulate_node_share if backend == "numpy" else simulate_node_share_jax
    )
    out = []
    for pol in policies:
        for n in node_counts:
            r = node_share(pol, total_fns, n, duration_s)
            out.append(
                ClusterResult(
                    policy=pol,
                    n_nodes=n,
                    p50=r.pct(50),
                    p95=r.pct(95),
                    thr_slo=r.throughput_slo(slo_s) * n,
                    util_effective=r.util_effective,
                    util_perceived=r.util_perceived,
                    overhead_frac=r.overhead_frac,
                )
            )
    return out


def min_nodes_meeting_slo(
    results: List[ClusterResult], policy: str, slo_s: float = 1.0,
    tail_factor: float = 2.0, median_factor: float = 2.5,
) -> int:
    """Smallest node count preserving the over-provisioned baseline's latency
    distribution (paper §5.1: consolidation must not degrade performance;
    the reference is the static-reservation cluster at max node count).
    Both the median and the p95 must stay within factor budgets — CFS shows
    'up to 6x' median/tail inflation when pushed past its limit."""
    base = [r for r in results if r.policy == policy]
    n_max = max(r.n_nodes for r in base)
    ref = min((r for r in results if r.n_nodes == n_max),
              key=lambda r: r.p95)  # over-provisioned reference
    p95_budget = max(tail_factor * ref.p95, slo_s)
    p50_budget = max(median_factor * ref.p50, 0.6)
    ok = [
        r.n_nodes for r in base
        if r.p95 <= p95_budget and r.p50 <= p50_budget
    ]
    return min(ok) if ok else n_max
