"""Cluster consolidation — compatibility shim over :mod:`repro.fleet`.

The consolidation study now lives in the placement-aware fleet layer
(``repro.fleet.consolidate`` hosts the sweep and the min-nodes search;
``repro.fleet.simulate`` runs real multi-node fleets, numpy or vmapped
JAX).  This module keeps the historical entry points importable:

  * :func:`consolidation_sweep` / :func:`min_nodes_meeting_slo` /
    :class:`ClusterResult` re-export the fleet implementations.
  * :func:`simulate_node_share` / :func:`simulate_node_share_jax` remain
    the legacy *single representative node* paths (one node simulated and
    scaled).  Note their known approximation: the share split floors to
    ``max(1, total_fns // n_nodes)``, dropping up to ``n_nodes - 1``
    functions from the cluster total — fleet placements conserve the
    function count instead (``repro.fleet.placement.Assignment`` asserts
    it), and ``tests/test_fleet.py`` pins both behaviors.
"""
from __future__ import annotations

import numpy as np

from repro.core.simkernel import SimConfig, SimResult, simulate
from repro.core.traces import make_workload
from repro.core.policies import make_policy
from repro.fleet.consolidate import (  # noqa: F401  (compat re-exports)
    ClusterResult,
    consolidation_sweep,
    min_nodes_meeting_slo,
)

__all__ = [
    "ClusterResult", "consolidation_sweep", "min_nodes_meeting_slo",
    "simulate_node_share", "simulate_node_share_jax",
]


def simulate_node_share(
    policy_name: str,
    total_fns: int,
    n_nodes: int,
    duration_s: float = 30.0,
    n_cores: int = 12,
    seed: int = 7,
) -> SimResult:
    """Simulate one representative node holding its share of the cluster.

    Legacy approximation (see module docstring): the per-node function
    count floors, so the simulated cluster can under-count by up to
    ``n_nodes - 1`` functions.  Use ``repro.fleet.simulate_fleet`` for the
    conserving multi-node path; when ``total_fns`` divides evenly the two
    agree exactly.
    """
    fns_per_node = max(1, total_fns // n_nodes)
    wl = make_workload(
        "azure2021", fns_per_node, duration_s=duration_s, n_cores=n_cores,
        seed=seed, exec_s=0.2,  # cluster-mode PyTorch-class requests (§3.2)
    )
    return simulate(
        wl, make_policy(policy_name),
        SimConfig(n_cores=n_cores, hierarchy_depth=5.0, burst_us=280.0,
                  seed=seed),
    )


def simulate_node_share_jax(
    policy_name: str,
    total_fns: int,
    n_nodes: int,
    duration_s: float = 30.0,
    n_cores: int = 12,
    seed: int = 7,
    threads_per_fn: int = 8,
) -> SimResult:
    """One representative node on the ``lax.scan`` backend.

    Same share split as :func:`simulate_node_share`, but through
    ``repro.core.simkernel_jax`` — any registered policy, jit-compiled,
    ``vmap``-able across the (n_nodes, policy) grid on an accelerator.
    Returned as a :class:`SimResult` so the SLO search is backend-blind
    (the scan backend folds switch time into ``overhead_s``; discrete
    switch counts stay numpy-only).  ``repro.fleet.simulate_fleet`` with
    ``backend="jax"`` batches *all* nodes of a configuration into one
    vmapped scan instead of scaling this single node.
    """
    from repro.core import simkernel_jax as sj
    from repro.sched.jax_backend import CODE_OF

    fns_per_node = max(1, total_fns // n_nodes)
    wl = make_workload(
        "azure2021", fns_per_node, duration_s=duration_s, n_cores=n_cores,
        seed=seed, exec_s=0.2, threads_per_fn=threads_per_fn,
    )
    trace = sj.build_slot_trace(wl, fns_per_node, threads_per_fn)
    p = sj.SimParams(
        n_cores=n_cores, n_fns=fns_per_node,
        n_ticks=int(duration_s / sj.TICK), policy=CODE_OF[policy_name],
        burst_us=280.0, depth=5.0,
    )
    out = sj.simulate(trace, p)
    lat = sj.latencies_from(trace, out["done_tick"])
    at = np.asarray(trace.arrival_tick)
    dt = np.asarray(out["done_tick"])
    ok = (dt >= 0) & (at < np.iinfo(np.int32).max // 2)
    fn_of = np.broadcast_to(
        np.asarray(trace.slot_fn)[:, None], at.shape
    )[ok]
    n_arrived = int((at < np.iinfo(np.int32).max // 2).sum())
    return SimResult(
        policy=policy_name,
        latencies=lat,
        fn_of=fn_of,
        arrival_of=at[ok] * sj.TICK,
        n_arrived=n_arrived,
        n_completed=len(lat),
        switches=0,
        switch_time_s=float(out["overhead_s"]),
        busy_time_s=float(out["busy_s"]),
        duration_s=duration_s,
        n_cores=n_cores,
    )
