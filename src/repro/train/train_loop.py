"""Train-step factory: grad accumulation, remat, optional grad compression.

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function ready for jit/pjit; ``abstract_state``/``state_pspecs`` provide the
ShapeDtypeStruct and PartitionSpec trees the dry-run lowers against without
allocating anything.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import grad_compress
from repro.models import model as model_lib
from repro.models import params as params_meta
from repro.models.params import spec_to_pspecs, spec_to_sds
from repro.train import optimizer as opt_lib


@dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1
    remat: bool = True
    compress_grads: bool = False  # int8 + error feedback (numerics-faithful)
    opt: opt_lib.OptConfig = opt_lib.OptConfig()


class TrainState(NamedTuple):
    params: dict
    opt: opt_lib.OptState


def init_state(cfg: ModelConfig, rng) -> TrainState:
    params = model_lib.init_params(cfg, rng)
    return TrainState(params=params, opt=opt_lib.init_state(params))


def abstract_state(cfg: ModelConfig) -> TrainState:
    """ShapeDtypeStruct tree of the full train state (no allocation)."""
    pspec = model_lib.abstract_params(cfg)
    params = spec_to_sds(pspec)
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return TrainState(
        params=params,
        opt=opt_lib.OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree_util.tree_map(f32, params),
            nu=jax.tree_util.tree_map(f32, params),
        ),
    )


def state_pspecs(cfg: ModelConfig, rules=None, mesh=None) -> TrainState:
    from jax.sharding import PartitionSpec as P

    pspec_tree = model_lib.abstract_params(cfg)
    pp = spec_to_pspecs(pspec_tree, rules=rules, mesh=mesh)
    return TrainState(
        params=pp,
        opt=opt_lib.OptState(
            step=P(),
            mu=jax.tree_util.tree_map(lambda x: x, pp),
            nu=jax.tree_util.tree_map(lambda x: x, pp),
        ),
    )


def make_train_step(cfg: ModelConfig, tc: TrainConfig):
    def loss_fn(params, batch):
        loss, metrics = model_lib.train_loss(params, cfg, batch, remat=tc.remat)
        return loss, metrics

    def grads_of(params, batch):
        if tc.accum_steps <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        # microbatch accumulation: split the global batch's leading axis
        n = tc.accum_steps

        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(jnp.float32), acc, g
            )
            return (acc, loss_acc + loss), ()

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        mbs = jax.tree_util.tree_map(
            lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch
        )
        (gsum, loss_sum), _ = jax.lax.scan(micro, (zeros, jnp.zeros(())), mbs)
        grads = jax.tree_util.tree_map(lambda g: g / n, gsum)
        return loss_sum / n, {"nll": loss_sum / n, "aux": jnp.zeros(())}, grads

    param_specs = model_lib.abstract_params(cfg)

    def train_step(state: TrainState, batch: dict):
        loss, metrics, grads = grads_of(state.params, batch)
        # keep gradients in the parameters' sharded layout (otherwise the
        # SPMD partitioner may run the whole optimizer replicated)
        grads = params_meta.constrain_like(grads, param_specs)
        if tc.compress_grads:
            grads = jax.tree_util.tree_map(
                lambda g: grad_compress.decompress(
                    *grad_compress.compress(g), dtype=g.dtype
                ),
                grads,
            )
        new_params, new_opt, om = opt_lib.apply_updates(
            state.params, grads, state.opt, tc.opt
        )
        metrics = dict(metrics, loss=loss, **om)
        return TrainState(new_params, new_opt), metrics

    return train_step


def instrument_step(step_fn, name: str = "train.step", tokens_per_step: int = 0):
    """Wrap a jitted ``(state, batch) -> (state, metrics)`` step with
    ``repro.obs`` telemetry: a ``block_until_ready``-fenced span (async
    dispatch otherwise makes a jitted step look ~free) feeding a step-time
    histogram and throughput counters.  With telemetry disabled the wrapper
    neither fences nor records — the step pipeline is untouched.
    """
    from repro.obs import metrics as obs_metrics
    from repro.obs import tracing as obs_tracing

    def wrapped(state, batch):
        with obs_tracing.fenced_span(name, cat="train") as sp:
            state, metrics = step_fn(state, batch)
            sp((state, metrics))
        if obs_metrics.enabled():
            obs_metrics.histogram(f"{name}.seconds").record(sp.dur_s)
            obs_metrics.counter(f"{name}.count").inc()
            if tokens_per_step:
                obs_metrics.counter(f"{name}.tokens").inc(tokens_per_step)
        return state, metrics

    return wrapped
