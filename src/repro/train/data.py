"""Deterministic synthetic data pipeline.

Sharded, resumable, and reproducible: batch ``i`` on data shard ``k`` is a
pure function of (seed, i, k) — no state to checkpoint beyond the step
counter, which makes restart-after-failure trivial (DESIGN.md §5).  Produces
token streams whose unigram statistics follow a Zipf distribution so the LM
loss has realistic structure (tests assert loss decreases over steps).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.configs.base import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2
    mask_frac: float = 0.0  # >0: masked-prediction (encoder archs)


def _zipf_probs(vocab: int, a: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-a)
    return p / p.sum()


class TokenStream:
    """Deterministic batch generator for one data shard."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 dc: DataConfig = DataConfig(), shard: int = 0,
                 n_shards: int = 1):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        self.dc, self.shard, self.n_shards = dc, shard, n_shards
        self._probs = _zipf_probs(min(cfg.vocab_size, 50_000), dc.zipf_a)

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng(
            (self.dc.seed * 1_000_003 + step) * 97 + self.shard
        )
        V = len(self._probs)
        toks = rng.choice(V, size=(self.batch, self.seq + 1), p=self._probs)
        # inject learnable bigram structure: every even position repeats
        # a function of the previous token
        toks[:, 1::2] = (toks[:, 0:-1:2] * 7 + 3) % V
        toks = toks.astype(np.int32)
        batch = {
            "tokens": toks[:, :-1],
            "targets": toks[:, 1:],
            "loss_mask": np.ones((self.batch, self.seq), np.float32),
        }
        if self.cfg.frontend == "audio_frames":
            emb = rng.standard_normal(
                (self.batch, self.seq, self.cfg.d_model)
            ).astype(np.float32)
            batch = {
                "frames": emb,
                "targets": (toks[:, 1:] % self.cfg.vocab_size).astype(np.int32),
                "loss_mask": batch["loss_mask"],
            }
        elif self.cfg.frontend == "vision":
            nv = self.cfg.n_vision_tokens
            batch["vision_embeds"] = rng.standard_normal(
                (self.batch, nv, self.cfg.d_model)
            ).astype(np.float32)
            pos = np.broadcast_to(
                np.arange(self.seq, dtype=np.int32)[None, :, None],
                (self.batch, self.seq, 3),
            ).copy()
            batch["positions"] = pos
        if self.dc.mask_frac > 0:
            m = rng.uniform(size=(self.batch, self.seq)) < self.dc.mask_frac
            batch["loss_mask"] = m.astype(np.float32)
        return batch
