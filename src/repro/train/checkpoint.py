"""Fault-tolerant sharded checkpointing (no orbax dependency).

Design for 1000+ nodes:
  * every host writes only its OWN shards (addressable devices) — here
    emulated by writing per-leaf ``.npy`` files keyed by flattened path;
  * atomic commit: write to ``step_N.tmp/``, fsync, rename to ``step_N/``
    and stamp a ``MANIFEST.json`` with per-file sha256 — a torn write is
    never visible as a valid checkpoint;
  * resume: ``latest_step`` scans for the highest committed manifest and
    verifies hashes before restore;
  * elastic re-mesh: checkpoints store the *global* logical arrays, so a
    restore may re-shard onto a different mesh (512 -> 448 healthy chips);
    ``restore(..., sharding_tree=...)`` places shards accordingly.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomically write a checkpoint; returns the committed directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "files": {}}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        manifest["files"][key] = {
            "file": fname,
            "sha256": _sha256(fpath),
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Highest step with a committed, hash-valid manifest."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            mf = os.path.join(ckpt_dir, d, "MANIFEST.json")
            if os.path.exists(mf):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def verify(ckpt_dir: str, step: int) -> bool:
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
    except (OSError, json.JSONDecodeError):
        return False
    for key, meta in manifest["files"].items():
        fpath = os.path.join(d, meta["file"])
        if not os.path.exists(fpath) or _sha256(fpath) != meta["sha256"]:
            return False
    return True


def restore(ckpt_dir: str, step: int, like: Any, sharding_tree: Any = None):
    """Restore into the structure of ``like`` (arrays or ShapeDtypeStructs).

    ``sharding_tree`` (optional, matching pytree of Shardings) re-shards
    every leaf for the CURRENT mesh — this is the elastic-rescale path.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    manifest = json.load(open(os.path.join(d, "MANIFEST.json")))
    flat_like = _flatten(like)
    flat_sh = _flatten(sharding_tree) if sharding_tree is not None else {}
    out = {}
    for key, meta in manifest["files"].items():
        arr = np.load(os.path.join(d, meta["file"]))
        if key in flat_sh and flat_sh[key] is not None:
            out[key] = jax.device_put(arr, flat_sh[key])
        else:
            out[key] = jax.numpy.asarray(arr)
    # rebuild tree in `like`'s structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    assert set(keys) == set(out.keys()), (
        f"checkpoint/like mismatch: {set(keys) ^ set(out.keys())}"
    )
    return treedef.unflatten([out[k] for k in keys])
