"""AdamW with cosine schedule, global-norm clipping (pure JAX, no optax).

Moments are fp32 regardless of parameter dtype; the update is computed in
fp32 and cast back.  State is a pytree matching params, so it inherits the
parameters' sharding (fully-sharded optimizer state = ZeRO-3-style for free
under the FSDP+TP rules in ``repro.distributed.sharding``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: dict
    nu: dict


def init_state(params) -> OptState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros32, params),
        nu=jax.tree_util.tree_map(zeros32, params),
    )


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def apply_updates(params, grads, state: OptState, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
