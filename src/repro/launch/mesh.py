"""Production mesh construction.

A function — not a module-level constant — so importing this module never
touches jax device state.  The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
