"""Serving driver: multi-tenant continuous batching with LAGS admission.

  PYTHONPATH=src python -m repro.launch.serve --policy lags --tenants 40 \
      --duration 30 --real-model

``--real-model`` attaches a reduced decoder so every engine step also runs a
jitted decode over the shared KV cache (proving the engine drives real
compute); without it the calibrated step-cost model is used (fast sweeps).

Telemetry: ``--obs-dir DIR`` records the run (schedstats + metrics) as a
diffable run record; ``--trace`` additionally captures a Chrome trace-event
file (open in Perfetto).  Compare policies with

  python -m repro.launch.serve --policy lags --obs-dir /tmp/r/lags
  python -m repro.launch.serve --policy fair --obs-dir /tmp/r/fair
  python -m repro.obs.report --diff /tmp/r/fair /tmp/r/lags

Long runs can be *watched live*: ``--checkpoint-every S`` rewrites the run
record every S sim-seconds, so ``python -m repro.obs.report DIR`` in
another shell always renders the latest snapshot.  Multiple engine shards
merge post-hoc into one fleet view:

  python -m repro.launch.serve --policy lags --shard s0 --obs-dir /tmp/f/s0
  python -m repro.launch.serve --policy lags --shard s1 --seed 1 \
      --obs-dir /tmp/f/s1
  python -m repro.obs.report --merge /tmp/f/s0 /tmp/f/s1
"""
from __future__ import annotations

import argparse

import numpy as np

import repro.obs as obs
from repro.core.traces import _mmpp_arrivals
from repro.obs import report as obs_report
from repro.obs.recorder import record_run
from repro.sched import serving as sched_serving
from repro.scheduler.tenant import Request, Tenant
from repro.serving.engine import Engine, EngineConfig


def build_workload(n_tenants: int, duration: float, seed: int = 0):
    rng = np.random.default_rng(seed)
    tenants = {
        i: Tenant(i, weight_mb=float(rng.uniform(32, 256)))
        for i in range(n_tenants)
    }
    rates = np.logspace(-1, 0.8, n_tenants)
    rates *= 28.0 / rates.sum()
    arrivals, rid = [], 0
    for t in range(n_tenants):
        for a in _mmpp_arrivals(rates[t], duration, rng, 1.0, 9.0):
            arrivals.append(
                Request(rid, t, int(rng.integers(64, 512)),
                        int(rng.integers(16, 128)), float(a))
            )
            rid += 1
    return tenants, arrivals


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default="lags",
                    choices=sorted(sched_serving.ADMISSION))
    ap.add_argument("--tenants", type=int, default=48)
    ap.add_argument("--duration", type=float, default=30.0)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--max-resident", type=int, default=12,
                    help="tenants whose weights fit in HBM (residency LRU)")
    ap.add_argument("--hysteresis", type=float, default=0.5,
                    help="LAGS preemption hysteresis: a waiting tenant "
                         "evicts only when credit < hysteresis * victim's")
    ap.add_argument("--pallas-threshold", type=int, default=256,
                    help="tenant count at which the credit tick moves onto "
                         "the fused Pallas kernel (0 = never)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--real-model", action="store_true")
    ap.add_argument("--obs-dir", default="",
                    help="record schedstats/metrics run record here")
    ap.add_argument("--trace", action="store_true",
                    help="capture a Chrome trace (needs --obs-dir to persist)")
    ap.add_argument("--checkpoint-every", type=float, default=0.0,
                    metavar="S",
                    help="stream live schedstats: rewrite the run record "
                         "every S sim-seconds (needs --obs-dir)")
    ap.add_argument("--shard", default="",
                    help="shard label recorded in the run meta, for "
                         "post-hoc `report --merge` of parallel shards")
    ap.add_argument("--admission-timeout", type=float, default=0.0,
                    metavar="S",
                    help="graceful degradation: expire requests still "
                         "queued S sim-seconds after arrival (0 = off)")
    ap.add_argument("--backoff-base", type=float, default=0.02,
                    metavar="S",
                    help="first out-of-pages backoff; doubles per "
                         "rejection (capped at 0.5s)")
    ap.add_argument("--shed-watermark", type=int, default=0,
                    help="overload shedding: total queue depth past which "
                         "the highest-credit tenants' work is shed (0 = "
                         "off)")
    ap.add_argument("--shed-mode", default="drop",
                    choices=("drop", "truncate"),
                    help="shed by dropping newest requests or by halving "
                         "their max_new once")
    ap.add_argument("--fence-window", action="append", default=[],
                    metavar="T0:T1",
                    help="fence the engine (serve in-flight only, defer new "
                         "admissions) over [T0, T1) sim-seconds; repeatable, "
                         "models a SUSPECT verdict from the health tracker")
    args = ap.parse_args(argv)

    fence_windows = []
    for w in args.fence_window:
        try:
            a, b = w.split(":")
            fence_windows.append((float(a), float(b)))
        except ValueError:
            ap.error(f"--fence-window expects T0:T1, got {w!r}")

    if args.obs_dir or args.trace:
        obs.enable()
    if args.trace:
        obs.install_tracer()

    tenants, arrivals = build_workload(args.tenants, args.duration, args.seed)
    eng = Engine(
        EngineConfig(policy=args.policy, n_slots=args.slots,
                     max_resident=args.max_resident,
                     preempt_hysteresis=args.hysteresis,
                     pallas_threshold=args.pallas_threshold,
                     admission_timeout_s=args.admission_timeout,
                     backoff_base_s=args.backoff_base,
                     shed_watermark=args.shed_watermark,
                     shed_mode=args.shed_mode),
        tenants,
    )
    if args.real_model:
        import jax

        from repro.configs.base import get_config, reduced
        from repro.models import model as model_lib

        cfg = reduced(get_config("qwen3-8b"), n_layers=2)
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        eng.attach_model(cfg, params, max_len=64)

    meta = {
        "layer": "serving", "policy": args.policy,
        "tenants": args.tenants, "duration_s": args.duration,
        "slots": args.slots, "seed": args.seed,
        "arrivals": len(arrivals),
    }
    if args.admission_timeout or args.shed_watermark:
        meta["degradation"] = {
            "admission_timeout_s": args.admission_timeout,
            "shed_watermark": args.shed_watermark,
            "shed_mode": args.shed_mode,
        }
    if fence_windows:
        meta["fence_windows"] = [[a, b] for a, b in fence_windows]
    if args.shard:
        meta["shard"] = args.shard

    n_ckpt = 0

    def _checkpoint(stats):
        # live schedstats stream: rewrite the run record in place so a
        # concurrent `repro.obs.report` sees the latest partial totals
        nonlocal n_ckpt
        n_ckpt += 1
        record_run(
            args.obs_dir,
            meta={**meta, "checkpoint": n_ckpt,
                  "progress_s": round(stats.time_s, 3), "live": True},
            sched=stats.sched,
        )

    st = eng.run(
        args.duration, arrivals,
        checkpoint_every_s=args.checkpoint_every if args.obs_dir else 0.0,
        on_checkpoint=_checkpoint if args.obs_dir else None,
        fence_windows=fence_windows or None,
    )
    lat = np.asarray([r.latency for r in st.completed])
    print(
        f"policy={args.policy} completed={len(st.completed)}/{len(arrivals)} "
        f"p50={np.median(lat) if len(lat) else -1:.2f}s "
        f"p95={np.percentile(lat, 95) if len(lat) else -1:.2f}s "
        f"switch_overhead={st.overhead_frac*100:.1f}% "
        f"membership_changes={st.membership_changes}"
        + (f" shed={st.shed} expired={st.expired} backoffs={st.backoffs}"
           if (st.shed or st.expired or st.backoffs) else "")
        + (f" fenced_steps={st.fenced_steps} deferred={st.deferred}"
           if (st.fenced_steps or st.deferred) else "")
        + (f" checkpoints={n_ckpt}" if n_ckpt else "")
    )
    if args.obs_dir:
        path = record_run(
            args.obs_dir,
            meta={**meta, "checkpoints": n_ckpt} if n_ckpt else meta,
            sched=st.sched,
        )
        print(f"run record -> {path}")
        print(obs_report.summarize({"meta": {"policy": args.policy},
                                    "sched": st.sched}))
    return st


if __name__ == "__main__":
    main()
