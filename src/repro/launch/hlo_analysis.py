"""Partitioned-HLO analysis (canonical implementation): collective wire bytes with while-loop trip
attribution, and the hardware roofline constants.

XLA prints each computation once; a collective inside a scan-over-layers
while body executes ``trip_count`` times.  We build the computation graph,
read each while loop's trip count from the integer constant in its condition
computation, and multiply collective volumes through nested loops.
"""
from __future__ import annotations

import re
from dataclasses import dataclass


@dataclass(frozen=True)
class Chip:
    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    link_bw: float = 50e9  # bytes/s per ICI link
    n_links: int = 4  # torus links per chip usable concurrently
    hbm_bytes: float = 16e9


CHIP = Chip()

DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

# wire-bytes factor per element of the op result (ring algorithms)
COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_COMP_NAME = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_COLLECTIVE = re.compile(
    r"=\s*(\(?[a-z0-9\[\],\s{}:]+\)?)\s+(all-gather|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_WHILE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CONST = re.compile(r"s(?:32|64)\[\]\s+constant\((\d+)\)")


def _tensor_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_computations(hlo_text: str) -> dict:
    """name -> {"collectives": [(kind, bytes)], "whiles": [(cond, body)],
    "consts": [int], "entry": bool}."""
    comps: dict = {}
    cur = None
    for line in hlo_text.splitlines():
        # computation headers start at column 0 and end with "{"; parameter
        # lists may contain nested tuple types, so match only the name.
        header = (
            line
            and not line[0].isspace()
            and line.rstrip().endswith("{")
            and "->" in line
        )
        m = _COMP_NAME.match(line) if header else None
        if m:
            cur = m.group(1)
            comps[cur] = {
                "collectives": [],
                "whiles": [],
                "consts": [],
                "entry": line.startswith("ENTRY"),
            }
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        mc = _COLLECTIVE.search(line)
        if mc:
            comps[cur]["collectives"].append(
                (mc.group(2), _tensor_bytes(mc.group(1)))
            )
        mw = _WHILE.search(line)
        if mw:
            comps[cur]["whiles"].append((mw.group(1), mw.group(2)))
        for mk in _CONST.finditer(line):
            comps[cur]["consts"].append(int(mk.group(1)))
    return comps


def collective_stats_attributed(hlo_text: str) -> dict:
    """Per-device wire bytes by kind, with while-loop trip multipliers."""
    comps = parse_computations(hlo_text)
    entry = next((n for n, c in comps.items() if c["entry"]), None)
    out = {k: {"count": 0, "bytes": 0.0} for k in COLLECTIVE_FACTOR}

    def trip_count(cond_name: str) -> int:
        c = comps.get(cond_name)
        if not c or not c["consts"]:
            return 1
        return max(1, max(c["consts"]))

    seen: set = set()

    def walk(name: str, mult: float):
        if name not in comps:
            return
        key = (name, mult)
        if key in seen:  # guard pathological recursion
            return
        seen.add(key)
        c = comps[name]
        for kind, b in c["collectives"]:
            out[kind]["count"] += 1
            out[kind]["bytes"] += b * COLLECTIVE_FACTOR[kind] * mult
        for cond, body in c["whiles"]:
            walk(body, mult * trip_count(cond))

    if entry:
        walk(entry, 1.0)
    else:  # fallback: flat sum
        for c in comps.values():
            for kind, b in c["collectives"]:
                out[kind]["count"] += 1
                out[kind]["bytes"] += b * COLLECTIVE_FACTOR[kind]
    out["total_bytes"] = sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict)
    )
    return out
