"""ShapeDtypeStruct stand-ins + PartitionSpecs for every (arch x shape) cell.

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs for
every model input — no device allocation — plus the matching PartitionSpec
trees and the step function to lower.  This is the single source of truth
used by the dry-run, the roofline benchmarks and the launch scripts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_skip_reason
from repro.distributed import sharding as sh
from repro.models import model as model_lib
from repro.models.params import spec_to_pspecs, spec_to_sds
from repro.train import train_loop

# Room for the new token past the cached prefix; 16 keeps the cache length
# divisible by the "model" mesh axis so KV-sequence sharding applies.
DECODE_PAD = 16

# Baseline microbatch (gradient-accumulation) factors for train_4k: standard
# production configs for the archs whose global-batch-256 activations exceed
# HBM on a v5e (16 GB) chip.  EXPERIMENTS.md §Dry-run records the footprints.
TRAIN_ACCUM = {
    "jamba-v0.1-52b": 16,
    "gemma3-27b": 8,
    "falcon-mamba-7b": 8,
    "qwen2-moe-a2.7b": 8,
    "qwen3-moe-235b-a22b": 8,
    "qwen2-vl-7b": 2,
}


@dataclass
class Lowerable:
    """Everything needed to jit().lower() one (arch x shape) cell."""

    fn: Callable
    args_sds: tuple  # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    rules: dict
    donate_argnums: tuple = ()


def _batch_sds(cfg: ModelConfig, shape: ShapeConfig, step: str, rules, mesh):
    B, S = shape.global_batch, shape.seq_len
    i32, f32 = jnp.int32, jnp.float32
    dt = jnp.dtype(cfg.dtype)
    def bp(sds, *names):
        return sh.to_pspec(names, rules=rules, mesh=mesh, shape=sds.shape)

    if step == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
        ps = {"tokens": bp(batch["tokens"], "batch", None)}
        if cfg.rope_kind == "mrope":
            batch["positions"] = jax.ShapeDtypeStruct((B, 1, 3), i32)
            ps["positions"] = bp(batch["positions"], "batch", None, None)
        return batch, ps
    if cfg.frontend == "audio_frames":
        batch = {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt)}
        ps = {"frames": bp(batch["frames"], "batch", None, None)}
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        ps = {"tokens": bp(batch["tokens"], "batch", None)}
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_vision_tokens, cfg.d_model), dt
        )
        ps["vision_embeds"] = bp(batch["vision_embeds"], "batch", None, None)
        batch["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
        ps["positions"] = bp(batch["positions"], "batch", None, None)
    if step == "train":
        batch["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        batch["loss_mask"] = jax.ShapeDtypeStruct((B, S), f32)
        ps["targets"] = bp(batch["targets"], "batch", None)
        ps["loss_mask"] = bp(batch["loss_mask"], "batch", None)
    return batch, ps


def build_lowerable(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh,
    tc: Optional[train_loop.TrainConfig] = None,
) -> Lowerable:
    step = shape.step
    rules = sh.DECODE_RULES if step == "decode" else sh.TRAIN_RULES
    batch_sds, batch_ps = _batch_sds(cfg, shape, step, rules, mesh)

    if step == "train":
        tc = tc or train_loop.TrainConfig(
            accum_steps=TRAIN_ACCUM.get(cfg.name, 1)
        )
        fn = train_loop.make_train_step(cfg, tc)
        state_sds = train_loop.abstract_state(cfg)
        state_ps = train_loop.state_pspecs(cfg, rules=rules, mesh=mesh)
        return Lowerable(
            fn=fn,
            args_sds=(state_sds, batch_sds),
            in_shardings=(state_ps, batch_ps),
            out_shardings=(state_ps, None),
            rules=rules,
            donate_argnums=(0,),
        )

    params_spec = model_lib.abstract_params(cfg)
    params_sds = spec_to_sds(params_spec)

    if step == "prefill":
        # long sequence: sequence-parallel residual (TRAIN_RULES)
        params_ps = spec_to_pspecs(params_spec, rules=rules, mesh=mesh)

        def prefill_fn(params, batch):
            return model_lib.prefill(params, cfg, batch, max_len=shape.seq_len)

        return Lowerable(
            fn=prefill_fn,
            args_sds=(params_sds, batch_sds),
            in_shardings=(params_ps, batch_ps),
            out_shardings=None,
            rules=rules,
        )

    # decode
    params_ps = spec_to_pspecs(params_spec, rules=rules, mesh=mesh)
    cache_spec = model_lib.cache_specs(cfg, shape.global_batch, shape.seq_len + DECODE_PAD)
    cache_sds = spec_to_sds(cache_spec)
    cache_ps = spec_to_pspecs(cache_spec, rules=rules, mesh=mesh)

    def decode_fn(params, batch, cache, cache_len):
        return model_lib.decode_step(params, cfg, batch, cache, cache_len)

    return Lowerable(
        fn=decode_fn,
        args_sds=(params_sds, batch_sds, cache_sds, jax.ShapeDtypeStruct((), jnp.int32)),
        in_shardings=(params_ps, batch_ps, cache_ps, None),
        out_shardings=None,
        rules=rules,
        donate_argnums=(2,),
    )


def cell_skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    return shape_skip_reason(cfg, SHAPES[shape_name])
