"""End-to-end training driver with fault-tolerant checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Resume is automatic: if the checkpoint dir holds a committed step, training
continues from it (deterministic data makes the stream seamless).  On a real
cluster this script runs per host under the launcher; here it drives the
single-process mesh.  ``--simulate-failure N`` exits hard at step N to
exercise the restart path (see tests/test_train_e2e.py).
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced
from repro.distributed.fault import StragglerWatchdog
from repro.train import checkpoint as ckpt_lib
from repro.train import train_loop
from repro.train.data import DataConfig, TokenStream
from repro.train.optimizer import OptConfig


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--simulate-failure", type=int, default=-1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--obs-dir", default="",
                    help="record step-time metrics (and trace) run record")
    ap.add_argument("--trace", action="store_true",
                    help="capture per-step Chrome trace events")
    args = ap.parse_args(argv)

    if args.obs_dir or args.trace:
        import repro.obs as obs

        obs.enable()
        if args.trace:
            obs.install_tracer()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    tc = train_loop.TrainConfig(
        accum_steps=args.accum,
        compress_grads=args.compress_grads,
        opt=OptConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps),
    )
    step_fn = jax.jit(train_loop.make_train_step(cfg, tc), donate_argnums=0)
    step_fn = train_loop.instrument_step(
        step_fn, tokens_per_step=args.batch * args.seq * max(args.accum, 1)
    )
    stream = TokenStream(cfg, args.batch, args.seq, DataConfig())

    start = 0
    state = train_loop.init_state(cfg, jax.random.PRNGKey(0))
    if args.ckpt_dir:
        latest = ckpt_lib.latest_step(args.ckpt_dir)
        if latest is not None and ckpt_lib.verify(args.ckpt_dir, latest):
            state = ckpt_lib.restore(args.ckpt_dir, latest, state)
            start = latest
            print(f"resumed from step {latest}")

    watchdog = StragglerWatchdog(n_hosts=1)
    losses = []
    for step in range(start, args.steps):
        t0 = time.time()
        batch = {k: jax.numpy.asarray(v) for k, v in stream.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        watchdog.observe(0, time.time() - t0)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step}: loss {loss:.4f} "
                  f"({time.time()-t0:.2f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            ckpt_lib.save(args.ckpt_dir, step + 1, state)
        if args.simulate_failure == step:
            print("simulating hard failure", file=sys.stderr)
            os._exit(17)
    if args.obs_dir:
        from repro.obs.recorder import record_run

        path = record_run(
            args.obs_dir,
            meta={
                "layer": "train", "arch": args.arch, "steps": args.steps,
                "batch": args.batch, "seq": args.seq, "accum": args.accum,
            },
        )
        print(f"run record -> {path}")
    return {"losses": losses, "final_step": args.steps}


if __name__ == "__main__":
    main()
