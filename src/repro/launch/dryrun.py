import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled module must fit
per-device memory, and the collective schedule is extracted for the roofline
analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_report.json
"""
import argparse
import json
import re
import sys
import time

import jax

from repro.configs.base import SHAPES, get_config, list_configs
from repro.distributed.sharding import sharding_ctx
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_lowerable, cell_skip_reason

from repro.launch.hlo_analysis import collective_stats_attributed as collective_stats


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_skip_reason(cfg, shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "step": shape.step,
    }
    if skip:
        cell["status"] = "skipped"
        cell["reason"] = skip
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    low = build_lowerable(cfg, shape, mesh)

    def to_ns(tree):
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.tree_util.tree_map(
            lambda ps: NamedSharding(mesh, ps),
            tree,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )

    with mesh:
        with sharding_ctx(mesh, low.rules):
            jitted = jax.jit(
                low.fn,
                in_shardings=to_ns(low.in_shardings),
                out_shardings=to_ns(low.out_shardings),
                donate_argnums=low.donate_argnums,
            )
            lowered = jitted.lower(*low.args_sds)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    hlo = compiled.as_text()
    coll = collective_stats(hlo)

    cell.update(
        status="ok",
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        collectives=coll,
        memory={
            k: int(getattr(mem, k, 0) or 0)
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
        },
    )
    if verbose:
        print(f"--- {arch} x {shape_name} x {mesh_name} ---")
        print("memory_analysis:", cell["memory"])
        print(
            f"cost_analysis: flops={cell['flops']:.3e} "
            f"bytes={cell['bytes_accessed']:.3e}"
        )
        print(
            "collectives: "
            + ", ".join(
                f"{k}:{v['count']}({v['bytes']/1e6:.1f}MB)"
                for k, v in coll.items()
                if isinstance(v, dict) and v["count"]
            )
            + f" | total {coll['total_bytes']/1e6:.1f} MB/device"
        )
        print(f"lower {t_lower:.1f}s compile {t_compile:.1f}s")
    return cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="every cell, both meshes")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--out", default=None, help="write JSON report")
    args = ap.parse_args()

    cells = []
    if args.all:
        combos = [
            (a, s, mp)
            for a in list_configs()
            for s in SHAPES
            for mp in ((False,) if args.single_pod_only else (False, True))
        ]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        combos = [(args.arch, args.shape, args.multi_pod)]

    failed = 0
    for arch, shape, mp in combos:
        try:
            cells.append(run_cell(arch, shape, mp))
        except Exception as e:  # noqa: BLE001 - report and continue
            failed += 1
            cells.append(
                {"arch": arch, "shape": shape,
                 "mesh": "2x16x16" if mp else "16x16",
                 "status": "FAILED", "error": repr(e)[:500]}
            )
            print(f"FAILED {arch} x {shape} x {'multi' if mp else 'single'}: {e!r}",
                  file=sys.stderr)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(cells, f, indent=1)
        print(f"wrote {args.out} ({len(cells)} cells, {failed} failed)")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
