"""Per-tenant/per-function scheduling accounting (the paper's measurement model).

Mirrors what ``/proc/schedstat`` + perf gave the paper (§3, Figs 3-10), for
every execution layer in this repo: useful vs switch-overhead seconds, switch
rate and per-switch cost, run delay (runnable -> running wait), and a bounded
run-queue-depth timeline.  One ``SchedStats`` instance per run; the DES
oracle, the tick simulator, and the serving engine all publish into it, so
``repro.obs.report`` can summarize and diff runs across layers and policies.

Accounting identity (asserted by tests for the engine, where every second is
attributed): ``useful_s + switch_s + idle_s == time_s``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.obs.metrics import Histogram

_TIMELINE_CAP = 4096  # runq samples kept; halved (decimated) when exceeded


@dataclass
class EntityStats:
    """One scheduled entity: a function cgroup (sim) or a tenant (serving)."""

    useful_s: float = 0.0
    switch_s: float = 0.0
    switches: float = 0.0
    same_group_switches: float = 0.0
    run_delay_s: float = 0.0
    runs: int = 0  # times dispatched after a wait
    arrived: int = 0
    completed: int = 0

    def merge(self, other: "EntityStats") -> "EntityStats":
        self.useful_s += other.useful_s
        self.switch_s += other.switch_s
        self.switches += other.switches
        self.same_group_switches += other.same_group_switches
        self.run_delay_s += other.run_delay_s
        self.runs += other.runs
        self.arrived += other.arrived
        self.completed += other.completed
        return self

    def to_dict(self) -> dict:
        return {
            "useful_s": self.useful_s,
            "switch_s": self.switch_s,
            "switches": self.switches,
            "same_group_switches": self.same_group_switches,
            "run_delay_s": self.run_delay_s,
            "runs": self.runs,
            "arrived": self.arrived,
            "completed": self.completed,
        }


class SchedStats:
    """Incremental scheduling accountant with per-entity breakdown."""

    def __init__(self, name: str = ""):
        self.name = name
        self.entities: Dict[int, EntityStats] = {}
        self.time_s = 0.0  # total accounted time (sim seconds)
        self.idle_s = 0.0
        self.useful_s = 0.0
        self.switch_s = 0.0
        self.switches = 0.0
        self.capacity_s = 0.0  # core-seconds offered (0 if 1-slot semantics)
        # seconds spent fenced (SUSPECT): serving in-flight work only, no
        # new admissions.  An annotation parallel to the conservation
        # identity, not a term in it — fenced time is still accounted as
        # useful/switch/idle by whatever ran during it.
        self.fenced_s = 0.0
        self.switch_cost_us = Histogram("switch_cost_us", lo=1e-3)
        self.run_delay = Histogram("run_delay_s")
        self.latency = Histogram("latency_s")
        self.runq_timeline: List[Tuple[float, float]] = []
        self._stride = 1
        self._tick = 0

    def _ent(self, entity: int) -> EntityStats:
        e = self.entities.get(entity)
        if e is None:
            e = self.entities[entity] = EntityStats()
        return e

    # -- accounting --------------------------------------------------------
    def account_time(self, s: float) -> None:
        self.time_s += s

    def account_idle(self, s: float) -> None:
        self.idle_s += s

    def account_useful(self, entity: int, s: float) -> None:
        self.useful_s += s
        self._ent(entity).useful_s += s

    def account_switch(self, entity: int, cost_s: float, n: float = 1.0,
                       same_group: bool = False) -> None:
        self.switches += n
        self.switch_s += cost_s
        e = self._ent(entity)
        e.switches += n
        e.switch_s += cost_s
        if same_group:
            e.same_group_switches += n
        if n > 0:
            self.switch_cost_us.record(1e6 * cost_s / n, weight=n)

    def account_fenced(self, s: float) -> None:
        """Accumulate wall time spent fenced (no-new-admissions mode)."""
        self.fenced_s += s

    def account_run_delay(self, entity: int, s: float) -> None:
        e = self._ent(entity)
        e.run_delay_s += s
        e.runs += 1
        self.run_delay.record(s)

    def account_arrival(self, entity: int, n: int = 1) -> None:
        self._ent(entity).arrived += n

    def account_completion(self, entity: int, latency_s: float) -> None:
        self._ent(entity).completed += 1
        self.latency.record(latency_s)

    def sample_runq(self, t: float, depth: float) -> None:
        """Bounded timeline: record every ``stride``-th sample; on overflow
        decimate by 2x so memory stays O(cap) over arbitrarily long runs."""
        self._tick += 1
        if self._tick % self._stride:
            return
        tl = self.runq_timeline
        tl.append((t, depth))
        if len(tl) >= _TIMELINE_CAP:
            del tl[::2]
            self._stride *= 2

    # -- derived -----------------------------------------------------------
    @property
    def switch_share(self) -> float:
        """Switch time as a share of accounted time (or of core capacity
        when the layer reported one, as the simulator does)."""
        denom = self.capacity_s if self.capacity_s > 0 else self.time_s
        return self.switch_s / max(denom, 1e-12)

    @property
    def mean_switch_cost_us(self) -> float:
        return 1e6 * self.switch_s / max(self.switches, 1e-12)

    def switch_rate(self) -> float:
        return self.switches / max(self.time_s, 1e-12)

    def conservation_error(self) -> float:
        """|useful + switch + idle - time| — ~0 for layers that attribute
        every accounted second (the serving engine)."""
        return abs(self.useful_s + self.switch_s + self.idle_s - self.time_s)

    def runq_peak(self) -> float:
        return max((d for _, d in self.runq_timeline), default=0.0)

    # -- aggregation -------------------------------------------------------
    def merge(self, other: "SchedStats") -> "SchedStats":
        """Fold another run's accounting into this one (fleet aggregation).

        Totals and per-entity stats sum; histograms merge bucket-wise
        (``Histogram.merge``).  Entity ids are summed by key — for fleet
        nodes these are per-node function ids, i.e. function *classes*
        under the banded placement; for serve shards they are global
        tenant ids.  ``time_s`` sums too: for parallel shards the merged
        view accounts aggregate shard-seconds, which keeps the
        conservation identity (``useful + switch + idle == time``) and
        makes ``switch_share`` the fleet-wide share.
        """
        self.time_s += other.time_s
        self.idle_s += other.idle_s
        self.useful_s += other.useful_s
        self.switch_s += other.switch_s
        self.switches += other.switches
        self.capacity_s += other.capacity_s
        self.fenced_s += other.fenced_s
        self.switch_cost_us.merge(other.switch_cost_us)
        self.run_delay.merge(other.run_delay)
        self.latency.merge(other.latency)
        for k, e in other.entities.items():
            self._ent(k).merge(e)
        if other.runq_timeline:
            tl = sorted(self.runq_timeline + other.runq_timeline)
            while len(tl) >= _TIMELINE_CAP:
                tl = tl[::2]
            self.runq_timeline = tl
        if not self.name:
            self.name = other.name
        elif other.name and other.name != self.name:
            self.name = f"{self.name}+{other.name}"
        return self

    @classmethod
    def merged(cls, stats, name: str = "") -> "SchedStats":
        """One fleet-wide view from an iterable of per-shard stats."""
        out = cls(name)
        for st in stats:
            out.merge(st)
        return out

    # -- (de)serialization -------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "name": self.name,
            "time_s": self.time_s,
            "idle_s": self.idle_s,
            "useful_s": self.useful_s,
            "switch_s": self.switch_s,
            "switches": self.switches,
            "capacity_s": self.capacity_s,
            "fenced_s": self.fenced_s,
            "switch_share": self.switch_share,
            "mean_switch_cost_us": self.mean_switch_cost_us,
            "switch_cost_us": self.switch_cost_us.to_dict(),
            "run_delay": self.run_delay.to_dict(),
            "latency": self.latency.to_dict(),
            "runq_timeline": [[t, d] for t, d in self.runq_timeline],
            "entities": {str(k): e.to_dict() for k, e in self.entities.items()},
        }

    @classmethod
    def from_snapshot(cls, d: dict) -> "SchedStats":
        st = cls(d.get("name", ""))
        st.time_s = d["time_s"]
        st.idle_s = d["idle_s"]
        st.useful_s = d["useful_s"]
        st.switch_s = d["switch_s"]
        st.switches = d["switches"]
        st.capacity_s = d.get("capacity_s", 0.0)
        st.fenced_s = d.get("fenced_s", 0.0)  # absent in pre-fence records
        st.switch_cost_us = Histogram.from_dict(
            d["switch_cost_us"], "switch_cost_us")
        st.run_delay = Histogram.from_dict(d["run_delay"], "run_delay_s")
        st.latency = Histogram.from_dict(d["latency"], "latency_s")
        st.runq_timeline = [tuple(x) for x in d.get("runq_timeline", [])]
        for k, e in d.get("entities", {}).items():
            st.entities[int(k)] = EntityStats(**e)
        return st


def from_sim_result(r) -> "SchedStats":
    """Summary SchedStats for a ``simkernel.SimResult`` (the simulator also
    attaches a richer one on ``r.schedstats`` when telemetry is enabled)."""
    st = SchedStats(f"simkernel.{r.policy}")
    st.time_s = r.duration_s
    st.capacity_s = r.n_cores * r.duration_s
    st.useful_s = r.busy_time_s
    st.switch_s = r.switch_time_s
    st.switches = float(r.switches)
    st.idle_s = max(st.capacity_s - r.busy_time_s - r.switch_time_s, 0.0)
    st.latency.record_many(r.latencies)
    return st
