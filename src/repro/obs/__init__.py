"""repro.obs — shared observability: metrics, tracing, schedstats, reports.

One subsystem backs every execution layer's accounting (DES oracle, tick
simulator, serving engine, train loop) so policy comparisons are exportable
and diffable instead of hand-rolled printouts:

  * ``metrics``    — process-wide counters/gauges/log-bucketed histograms
  * ``tracing``    — bounded ring-buffer tracer, Chrome trace-event export
  * ``schedstats`` — per-tenant/per-function scheduling accounting
  * ``recorder``   — persist a run as a diffable ``run.json`` (+ trace)
  * ``report``     — ``python -m repro.obs.report`` summaries and run diffs

Telemetry is opt-in: ``obs.enable()`` turns on the registry helpers;
``obs.install_tracer()`` additionally captures trace events.  Disabled-path
cost is one branch per instrumented call site.
"""
from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    counter,
    disable,
    enable,
    enabled,
    gauge,
    histogram,
    registry,
)
from repro.obs.schedstats import EntityStats, SchedStats  # noqa: F401
from repro.obs.tracing import (  # noqa: F401
    Tracer,
    fenced_span,
    span,
    tracer,
)
from repro.obs.tracing import install as install_tracer  # noqa: F401
from repro.obs.tracing import uninstall as uninstall_tracer  # noqa: F401

__all__ = [
    "Counter", "Gauge", "Histogram", "SchedStats", "EntityStats", "Tracer",
    "counter", "gauge", "histogram", "registry", "enable", "disable",
    "enabled", "span", "fenced_span", "tracer", "install_tracer",
    "uninstall_tracer",
]
