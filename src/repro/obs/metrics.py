"""Process-wide metrics registry: counters, gauges, log-bucketed histograms.

The schedstat analogue for this repo: every execution layer (DES oracle,
tick simulator, serving engine, train loop) publishes through one registry
so policy comparisons are backed by exportable numbers instead of ad-hoc
printouts.

Cost model:
  * Instruments (``Counter``/``Gauge``/``Histogram``) always record — they
    are plain objects owned by whoever created them (e.g. a ``SchedStats``).
  * The *module-level* helpers (``counter()``/``gauge()``/``histogram()``)
    are the hot-path API: when telemetry is disabled they hand back a shared
    null instrument, so an instrumented call site costs one branch.

Histograms are log-bucketed (geometric bucket edges): a fixed per-bucket
relative width buys O(1) record cost and quantiles within ~half a bucket of
numpy's over any dynamic range — the same trick as hdrhistogram / Prometheus
native histograms.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, Optional

_ENABLED = False


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    global _ENABLED
    _ENABLED = False


def enabled() -> bool:
    return _ENABLED


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def to_dict(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str = ""):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_dict(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Log-bucketed histogram with interpolated quantiles.

    Bucket ``i`` covers ``[lo * growth**i, lo * growth**(i+1))``; the default
    growth of 2**(1/8) (8 buckets per doubling) bounds quantile relative
    error at ~4.4 % (half a bucket, geometric midpoint read-out).  Values
    ``<= 0`` land in a dedicated zero bucket; values below ``lo`` clamp to
    bucket 0.  Counts are floats so aggregate paths (e.g. the simulator's
    per-tick voluntary-switch rates) can record fractional weights.
    """

    __slots__ = ("name", "lo", "growth", "_log_growth", "buckets", "zero",
                 "count", "sum", "min", "max")

    def __init__(self, name: str = "", lo: float = 1e-9,
                 growth: float = 2.0 ** 0.125):
        self.name = name
        self.lo = lo
        self.growth = growth
        self._log_growth = math.log(growth)
        self.buckets: Dict[int, float] = {}
        self.zero = 0.0
        self.count = 0.0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def _index(self, x: float) -> int:
        return max(0, int(math.log(x / self.lo) / self._log_growth))

    def record(self, x: float, weight: float = 1.0) -> None:
        x = float(x)
        self.count += weight
        self.sum += x * weight
        if x < self.min:
            self.min = x
        if x > self.max:
            self.max = x
        if x <= 0.0:
            self.zero += weight
            return
        i = self._index(x)
        self.buckets[i] = self.buckets.get(i, 0.0) + weight

    def record_many(self, xs: Iterable[float]) -> None:
        """Vectorised record for numpy arrays (used by the tick simulator)."""
        import numpy as np

        xs = np.asarray(xs, dtype=float).ravel()
        if xs.size == 0:
            return
        self.count += xs.size
        self.sum += float(xs.sum())
        self.min = min(self.min, float(xs.min()))
        self.max = max(self.max, float(xs.max()))
        pos = xs[xs > 0.0]
        self.zero += float(xs.size - pos.size)
        if pos.size:
            idx = np.maximum(
                0, (np.log(pos / self.lo) / self._log_growth).astype(np.int64)
            )
            uniq, cnt = np.unique(idx, return_counts=True)
            for i, c in zip(uniq.tolist(), cnt.tolist()):
                self.buckets[i] = self.buckets.get(i, 0.0) + c

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def pct(self, q: float) -> float:
        """Percentile in [0, 100] (numpy convention), geometric-midpoint
        read-out clamped to the observed [min, max]."""
        if self.count <= 0:
            return float("nan")
        rank = self.count * q / 100.0
        if rank <= self.zero:
            return max(0.0, self.min) if self.min < math.inf else 0.0
        cum = self.zero
        for i in sorted(self.buckets):
            cum += self.buckets[i]
            if cum >= rank - 1e-12:
                edge_lo = self.lo * self.growth ** i
                edge_hi = edge_lo * self.growth
                mid = math.sqrt(edge_lo * edge_hi)
                return min(max(mid, self.min), self.max)
        return self.max

    @property
    def p50(self) -> float:
        return self.pct(50)

    @property
    def p95(self) -> float:
        return self.pct(95)

    @property
    def p99(self) -> float:
        return self.pct(99)

    def merge(self, other: "Histogram") -> "Histogram":
        assert abs(other.growth - self.growth) < 1e-12 and other.lo == self.lo
        for i, c in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0.0) + c
        self.zero += other.zero
        self.count += other.count
        self.sum += other.sum
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def to_dict(self) -> dict:
        return {
            "type": "histogram",
            "lo": self.lo,
            "growth": self.growth,
            "zero": self.zero,
            "count": self.count,
            "sum": self.sum,
            "min": None if self.min == math.inf else self.min,
            "max": None if self.max == -math.inf else self.max,
            "buckets": {str(i): c for i, c in self.buckets.items()},
            "p50": self.pct(50),
            "p95": self.pct(95),
            "p99": self.pct(99),
        }

    @classmethod
    def from_dict(cls, d: dict, name: str = "") -> "Histogram":
        h = cls(name, lo=d["lo"], growth=d["growth"])
        h.zero = d["zero"]
        h.count = d["count"]
        h.sum = d["sum"]
        h.min = math.inf if d["min"] is None else d["min"]
        h.max = -math.inf if d["max"] is None else d["max"]
        h.buckets = {int(i): c for i, c in d["buckets"].items()}
        return h


class _NullInstrument:
    """Shared no-op stand-in returned by the module helpers when disabled."""

    __slots__ = ()
    name = ""
    value = 0.0
    count = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def record(self, x: float, weight: float = 1.0) -> None:
        pass

    def record_many(self, xs) -> None:
        pass


NULL = _NullInstrument()


class Registry:
    """Name -> instrument map; one process-wide instance (``registry()``)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {type(m).__name__}"
                )
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self) -> dict:
        with self._lock:
            return {name: m.to_dict() for name, m in self._metrics.items()}

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()


_REGISTRY = Registry()


def registry() -> Registry:
    return _REGISTRY


def counter(name: str) -> Counter:
    return _REGISTRY.counter(name) if _ENABLED else NULL  # type: ignore


def gauge(name: str) -> Gauge:
    return _REGISTRY.gauge(name) if _ENABLED else NULL  # type: ignore


def histogram(name: str) -> Histogram:
    return _REGISTRY.histogram(name) if _ENABLED else NULL  # type: ignore
