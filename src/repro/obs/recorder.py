"""Run recording: persist one run's telemetry as a diffable artifact.

A *run record* is a directory holding ``run.json`` (meta + schedstats +
metrics-registry snapshot) and optionally ``trace.json`` (Chrome trace
events).  ``repro.obs.report`` consumes these to summarize a run or diff two
(e.g. a lags run against a fair run of ``launch/serve.py``).
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro.obs import metrics as metrics_mod
from repro.obs import tracing as tracing_mod
from repro.obs.schedstats import SchedStats

RUN_FILE = "run.json"
TRACE_FILE = "trace.json"


def record_run(
    out_dir: str,
    meta: dict,
    sched: Optional[SchedStats] = None,
    include_registry: bool = True,
    tracer: Optional[tracing_mod.Tracer] = None,
    chaos: Optional[dict] = None,
) -> str:
    """Write a run record; returns the path of ``run.json``.

    ``tracer`` defaults to the installed process tracer (if any); pass a
    tracer explicitly to export one you drove by hand.  ``chaos`` attaches
    a failover report (``repro.fleet.rebalance.ChaosFleetResult.report()``)
    that ``repro.obs.report`` renders as the ``failover:`` section.
    """
    os.makedirs(out_dir, exist_ok=True)
    obj = {
        "version": 1,
        "meta": dict(meta),
        "schedstats": sched.snapshot() if sched is not None else None,
        "metrics": (
            metrics_mod.registry().snapshot() if include_registry else {}
        ),
    }
    if chaos is not None:
        obj["chaos"] = dict(chaos)
    if tracer is None:
        tracer = tracing_mod.tracer()
    if tracer is not None and len(tracer):
        tracer.export(os.path.join(out_dir, TRACE_FILE))
        obj["trace_file"] = TRACE_FILE
    path = os.path.join(out_dir, RUN_FILE)
    with open(path, "w") as f:
        json.dump(obj, f)
    return path


def load_run(path: str) -> dict:
    """Load a run record from a directory or a run.json path.  The parsed
    schedstats snapshot is rehydrated under the ``"sched"`` key."""
    if os.path.isdir(path):
        path = os.path.join(path, RUN_FILE)
    with open(path) as f:
        obj = json.load(f)
    snap = obj.get("schedstats")
    obj["sched"] = SchedStats.from_snapshot(snap) if snap else None
    obj["path"] = path
    return obj
