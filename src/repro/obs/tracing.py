"""Bounded ring-buffer event tracer with Chrome trace-event JSON export.

Spans are recorded as complete ("ph": "X") trace events into a fixed-size
ring; ``export()`` writes the Chrome trace-event format that Perfetto /
chrome://tracing load directly.  ``fenced_span`` is the JAX-aware timer: the
caller registers jitted outputs on the fence and the span closes only after
``jax.block_until_ready`` — otherwise async dispatch makes a jitted step
look ~free.

The tracer is off unless installed (``install()``); the module-level
``span``/``fenced_span`` helpers degrade to no-ops, so instrumented hot
paths cost one check per call when tracing is disabled.
"""
from __future__ import annotations

import json
import time
from collections import deque
from typing import Deque, List, Optional

from repro.obs import metrics as _metrics


class Span:
    """Context manager for one complete trace event.

    Also usable as the fence for jitted work: call the span with the jax
    outputs to block on (``fence(x)`` returns ``x``), and the duration is
    measured after ``block_until_ready``.  ``dur_s`` is valid after exit
    even when the owning tracer is a no-op, so callers can feed metrics.
    """

    __slots__ = ("tracer", "name", "cat", "args", "fenced", "_pending",
                 "_t0_ns", "dur_s")

    def __init__(self, tracer: Optional["Tracer"], name: str, cat: str,
                 fenced: bool = False, **args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.fenced = fenced
        self._pending: List[object] = []
        self._t0_ns = 0
        self.dur_s = 0.0

    def __call__(self, x):
        if self.fenced:
            self._pending.append(x)
        return x

    def __enter__(self) -> "Span":
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> None:
        if self._pending:
            import jax

            jax.block_until_ready(self._pending)
            self._pending.clear()
        dt_ns = time.perf_counter_ns() - self._t0_ns
        self.dur_s = dt_ns * 1e-9
        if self.tracer is not None:
            self.tracer.emit(
                self.name, self.cat,
                (self._t0_ns - self.tracer._t0_ns) / 1e3, dt_ns / 1e3,
                self.args,
            )


class Tracer:
    """Fixed-capacity ring buffer of Chrome trace events (oldest dropped)."""

    def __init__(self, capacity: int = 65536, pid: int = 0):
        self.capacity = capacity
        self.pid = pid
        self._events: Deque[dict] = deque(maxlen=capacity)
        self._t0_ns = time.perf_counter_ns()
        self.dropped = 0

    def __len__(self) -> int:
        return len(self._events)

    def now_us(self) -> float:
        return (time.perf_counter_ns() - self._t0_ns) / 1e3

    def emit(self, name: str, cat: str, ts_us: float, dur_us: float,
             args: Optional[dict] = None, ph: str = "X", tid: int = 0) -> None:
        if len(self._events) == self.capacity:
            self.dropped += 1
        ev = {
            "name": name, "cat": cat, "ph": ph,
            "ts": ts_us, "pid": self.pid, "tid": tid,
        }
        if ph == "X":
            ev["dur"] = dur_us
        if args:
            ev["args"] = dict(args)
        self._events.append(ev)

    def span(self, name: str, cat: str = "obs", **args) -> Span:
        return Span(self, name, cat, **args)

    def fenced_span(self, name: str, cat: str = "jax", **args) -> Span:
        return Span(self, name, cat, fenced=True, **args)

    def instant(self, name: str, cat: str = "obs", **args) -> None:
        self.emit(name, cat, self.now_us(), 0.0, args, ph="i")

    def counter(self, name: str, **series: float) -> None:
        """Chrome counter-track sample (renders as a stacked area chart)."""
        self.emit(name, "counter", self.now_us(), 0.0, series, ph="C")

    def events(self) -> List[dict]:
        return sorted(self._events, key=lambda e: e["ts"])

    def export(self, path: Optional[str] = None) -> dict:
        obj = {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"dropped_events": self.dropped},
        }
        if path is not None:
            with open(path, "w") as f:
                json.dump(obj, f)
        return obj


_TRACER: Optional[Tracer] = None

_NULL_SPAN_ARGS = dict(tracer=None, name="", cat="")


def install(capacity: int = 65536) -> Tracer:
    """Install (or replace) the process tracer and enable telemetry."""
    global _TRACER
    _TRACER = Tracer(capacity)
    _metrics.enable()
    return _TRACER


def uninstall() -> None:
    global _TRACER
    _TRACER = None


def tracer() -> Optional[Tracer]:
    return _TRACER


def active() -> bool:
    return _TRACER is not None and _metrics.enabled()


def span(name: str, cat: str = "obs", **args) -> Span:
    t = _TRACER if active() else None
    return Span(t, name, cat, **args)


def fenced_span(name: str, cat: str = "jax", **args) -> Span:
    # Fence only when telemetry is on: an unconditional block_until_ready
    # would serialize async dispatch even with observability disabled.
    t = _TRACER if active() else None
    return Span(t, name, cat, fenced=_metrics.enabled(), **args)
