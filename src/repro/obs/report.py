"""Run summaries, run-vs-run diffs and fleet merges over recorded telemetry.

  PYTHONPATH=src python -m repro.obs.report RUNDIR            # summarize
  PYTHONPATH=src python -m repro.obs.report --diff A B        # compare runs
  PYTHONPATH=src python -m repro.obs.report RUNDIR --top 5    # busiest tenants
  PYTHONPATH=src python -m repro.obs.report --merge D1 D2 ... # one fleet view

The diff is the paper's evaluation loop in one command: record a lags run
and a fair run of ``launch/serve.py`` (``--obs-dir``), then diff them to get
per-policy switch-time share, switch rate/cost, and latency-tail deltas.

``--merge`` folds many per-node / per-shard run records (fleet node
records from ``repro.fleet.simulate_fleet(record_dir=...)``, or several
``launch/serve.py --obs-dir`` shards) into a single fleet view: totals and
per-entity stats summed, histograms merged bucket-wise, plus a per-shard
breakdown table.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from repro.obs.recorder import load_run
from repro.obs.schedstats import SchedStats


def _fmt(v: Optional[float], unit: str = "") -> str:
    if v is None or v != v:  # NaN
        return "-"
    if unit == "%":
        return f"{100.0 * v:.2f}%"
    if unit == "us":
        return f"{v:.1f}us"
    if unit == "s":
        return f"{v:.3f}s"
    return f"{v:.3f}"


def _table(headers: List[str], rows: List[List[str]]) -> str:
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    def line(cells):
        return "  ".join(c.ljust(w) for c, w in zip(cells, widths)).rstrip()
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])


def _key_rows(sched: SchedStats) -> List[tuple]:
    lat, rdel = sched.latency, sched.run_delay
    return [
        ("time_s", sched.time_s, "s"),
        ("useful_s", sched.useful_s, "s"),
        ("switch_s", sched.switch_s, "s"),
        ("fenced_s", sched.fenced_s, "s"),
        ("switch_share", sched.switch_share, "%"),
        ("switches", sched.switches, ""),
        ("switch_rate_hz", sched.switch_rate(), ""),
        ("mean_switch_cost", 1e-6 * sched.mean_switch_cost_us, "s"),
        ("p99_switch_cost", 1e-6 * sched.switch_cost_us.pct(99), "s"),
        ("p50_latency", lat.pct(50), "s"),
        ("p95_latency", lat.pct(95), "s"),
        ("p99_latency", lat.pct(99), "s"),
        ("completed", lat.count, ""),
        ("p95_run_delay", rdel.pct(95), "s"),
        ("runq_peak", sched.runq_peak(), ""),
    ]


def _fault_node(e: dict) -> str:
    """Render a fault event's scope: a node, a rack, a node set or fleet."""
    if e.get("rack", -1) >= 0:
        return f"rack{e['rack']}"
    nodes = e.get("nodes") or []
    if nodes:
        return ",".join(str(n) for n in nodes)
    return "fleet" if e.get("node", -1) < 0 else str(e.get("node"))


def _failover_section(ch: dict) -> List[str]:
    """Render a chaos/failover report (attached by
    ``repro.fleet.record_chaos``): what was injected, what moved, how fast
    the fleet recovered, and SLO attainment inside degraded windows.

    A fault-free chaos record (empty schedule) renders ``∅`` for every
    fault-derived metric instead of degenerate zeros — 0 migrations after
    an injected crash and 0 migrations because nothing was injected are
    different facts, and recovery/SLO math over no faults is meaningless.
    """
    evs = ch.get("events", [])
    if not evs:
        rows = [
            ["injected events", "∅ (fault-free run)"],
            ["epochs",
             f"{ch.get('epochs')} x {_fmt(ch.get('epoch_s'), 's')}"],
            ["migrations", "∅"],
            ["stranded/replayed", "∅"],
            ["recovery", "∅"],
            ["degraded_slo_attainment", "∅"],
            ["completed/arrived",
             f"{ch.get('completed')}/{ch.get('arrived')} "
             f"({_fmt(ch.get('done_ratio'), '%')})"],
        ]
        return ["", "failover: ∅", _table(["metric", "value"], rows)]
    erows = [
        [_fmt(e.get("t"), "s"), str(e.get("kind")), _fault_node(e),
         _fmt(e.get("factor"))]
        for e in evs
    ]
    rec = ch.get("recovery_s", {}) or {}
    rec_txt = ", ".join(
        f"node{n}={'never' if v is None else _fmt(v, 's')}"
        for n, v in sorted(rec.items())
    ) or "∅ (no node crashed)"
    rows = [
        ["epochs", f"{ch.get('epochs')} x {_fmt(ch.get('epoch_s'), 's')}"],
        ["rebalanced", str(ch.get("rebalanced"))],
        ["migrations", str(ch.get("migrations", 0))],
        ["migration_s", _fmt(ch.get("migration_s"), "s")],
        ["stranded/replayed",
         f"{ch.get('stranded_arrivals', 0)}/{ch.get('replayed_arrivals', 0)}"],
        ["lost_arrivals", str(ch.get("lost_arrivals", 0))],
        ["completed/arrived",
         f"{ch.get('completed')}/{ch.get('arrived')} "
         f"({_fmt(ch.get('done_ratio'), '%')})"],
        ["recovery", rec_txt],
        ["degraded_slo_attainment",
         _fmt(ch.get("degraded_slo_attainment"), "%")],
    ]
    drained = ch.get("stragglers_drained") or []
    if drained:
        rows.append(["stragglers_drained",
                     ", ".join(str(s) for s in drained)])
    # topology-aware liveness ladder: only rendered when the run exercised
    # it (suspects seen, arrivals deferred off fenced nodes, or the
    # proactive drainer touched a node)
    suspects = ch.get("suspect_nodes") or []
    if suspects:
        rows.append(["suspect_nodes",
                     ", ".join(str(s) for s in suspects)])
    fenced = ch.get("fenced_nodes") or []
    if fenced:
        rows.append(["fenced_nodes", ", ".join(str(s) for s in fenced)])
        rows.append(["deferred/reconciled",
                     f"{ch.get('deferred_arrivals', 0)}"
                     f"/{ch.get('reconciled', 0)}"])
    pro_drained = ch.get("drained_nodes") or []
    if ch.get("proactive_drain"):
        rows.append(["proactive_drained",
                     ", ".join(str(s) for s in pro_drained) or "∅"])
    out = ["", "failover:", _table(["metric", "value"], rows)]
    out += ["", "injected events:",
            _table(["t", "kind", "node", "factor"], erows)]
    counts = ch.get("per_epoch_counts") or []
    if counts:
        out += ["", "per-epoch node fn counts:"]
        out += [f"  epoch {i}: {c}" for i, c in enumerate(counts)]
    live = ch.get("per_epoch_liveness") or []
    if live and (suspects or fenced or pro_drained):
        out += ["", "per-epoch liveness (live/suspect/fenced/draining):"]
        out += [f"  epoch {i}: {lv['live']}/{lv['suspect']}"
                f"/{lv['fenced']}/{lv['draining']}"
                for i, lv in enumerate(live)]
    return out


def summarize(run: dict, top: int = 0) -> str:
    meta = run.get("meta", {})
    sched: Optional[SchedStats] = run.get("sched")
    chaos = run.get("chaos")
    head = " ".join(f"{k}={v}" for k, v in sorted(meta.items()))
    out = [f"run: {head}" if head else "run: (no meta)"]
    if sched is None:
        out.append("(no schedstats recorded)")
        if chaos:
            out.extend(_failover_section(chaos))
        return "\n".join(out)
    rows = [[name, _fmt(val, unit)] for name, val, unit in _key_rows(sched)]
    out.append(_table(["metric", "value"], rows))
    if chaos:
        out.extend(_failover_section(chaos))
    if top > 0 and sched.entities:
        ents = sorted(sched.entities.items(),
                      key=lambda kv: kv[1].useful_s, reverse=True)[:top]
        erows = [
            [str(tid), _fmt(e.useful_s, "s"), _fmt(e.switch_s, "s"),
             _fmt(e.switches), _fmt(e.run_delay_s, "s"),
             f"{e.completed}/{e.arrived}" if e.arrived else str(e.completed)]
            for tid, e in ents
        ]
        out.append("")
        out.append(f"top {len(ents)} entities by useful_s:")
        out.append(_table(
            ["entity", "useful_s", "switch_s", "switches", "run_delay_s",
             "done"], erows))
    return "\n".join(out)


def merge(runs: List[dict]) -> str:
    """One fleet view over many per-node/per-shard run records."""
    scheds = [r.get("sched") for r in runs]
    missing = [r.get("path", "?") for r, s in zip(runs, scheds) if s is None]
    if missing:
        return f"merge requires schedstats in every run; missing in {missing}"
    merged = SchedStats.merged(scheds)
    metas = [r.get("meta", {}) for r in runs]
    policies = sorted({str(m.get("policy")) for m in metas if "policy" in m})
    head = [f"fleet view: {len(runs)} run records merged"]
    if policies:
        head.append(f"policies: {', '.join(policies)}")
    srows = []
    for r, s, m in zip(runs, scheds, metas):
        label = str(
            m.get("shard", m.get("node", os.path.basename(
                os.path.dirname(r.get("path", "run")))))
        )
        srows.append([
            label, str(m.get("policy", "-")), _fmt(s.time_s, "s"),
            _fmt(s.switch_share, "%"), _fmt(s.latency.pct(95), "s"),
            _fmt(s.latency.count),
        ])
    out = [
        " | ".join(head),
        "",
        "per-shard:",
        _table(["shard", "policy", "time_s", "switch_share", "p95_latency",
                "completed"], srows),
        "",
        "merged:",
        _table(["metric", "value"],
               [[name, _fmt(val, unit)]
                for name, val, unit in _key_rows(merged)]),
    ]
    # a chaos fleet's top-level record carries the failover report — keep
    # it visible in the merged fleet view too
    for r in runs:
        if r.get("chaos"):
            out.extend(_failover_section(r["chaos"]))
            break
    return "\n".join(out)


def diff(run_a: dict, run_b: dict) -> str:
    """Side-by-side comparison; delta column is B - A (negative = B lower)."""
    sa, sb = run_a.get("sched"), run_b.get("sched")
    if sa is None or sb is None:
        return "diff requires schedstats in both runs"
    la = str(run_a.get("meta", {}).get("policy", "A"))
    lb = str(run_b.get("meta", {}).get("policy", "B"))
    if la == lb:
        la, lb = f"{la}(A)", f"{lb}(B)"
    rows = []
    for (name, va, unit), (_, vb, _) in zip(_key_rows(sa), _key_rows(sb)):
        d = vb - va if va == va and vb == vb else float("nan")
        rows.append([name, _fmt(va, unit), _fmt(vb, unit), _fmt(d, unit)])
    out = [
        f"diff: {la} -> {lb}",
        _table(["metric", la, lb, f"delta({lb}-{la})"], rows),
    ]
    if sa.switch_share == sa.switch_share and sb.switch_share == sb.switch_share:
        lo = la if sa.switch_share <= sb.switch_share else lb
        out.append(f"lower switch-time share: {lo}")
    return "\n".join(out)


def main(argv=None) -> str:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize or diff recorded obs runs.",
    )
    ap.add_argument("runs", nargs="*", help="run dir(s) or run.json path(s)")
    ap.add_argument("--diff", action="store_true",
                    help="compare exactly two runs (delta = second - first)")
    ap.add_argument("--merge", action="store_true",
                    help="merge all given runs into one fleet view")
    ap.add_argument("--top", type=int, default=0,
                    help="also list the N busiest entities (summary mode)")
    args = ap.parse_args(argv)
    if args.diff and args.merge:
        ap.error("--diff and --merge are mutually exclusive")

    def _load(path):
        try:
            return load_run(path)
        except FileNotFoundError:
            ap.error(f"no run record at {path!r} (expected a dir with "
                     f"run.json, or a run.json path)")
        except (OSError, ValueError) as e:
            ap.error(f"could not read run record {path!r}: {e}")

    if args.diff:
        if len(args.runs) != 2:
            ap.error("--diff takes exactly two run paths")
        text = diff(_load(args.runs[0]), _load(args.runs[1]))
    elif args.merge:
        if len(args.runs) < 2:
            ap.error("--merge takes at least two run paths")
        text = merge([_load(p) for p in args.runs])
    else:
        if not args.runs:
            ap.error("give at least one run path")
        text = "\n\n".join(
            summarize(_load(p), top=args.top) for p in args.runs
        )
    try:
        print(text)
    except BrokenPipeError:  # e.g. `report ... | head`
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
    return text


if __name__ == "__main__":
    main()
