"""Jitted public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the same call sites work in both environments.  The model stack selects
these via ``use_pallas``; the XLA paths in ``repro.models`` remain the
dry-run/compile path (Pallas does not lower on the CPU backend).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import lags_select as _lags
from repro.kernels import ssm_scan as _ssm


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128):
    return _fa.flash_attention(
        q, k, v, causal=causal, window=window, bq=bq, bk=bk,
        interpret=_default_interpret(),
    )


@functools.partial(jax.jit, static_argnames=("bk",))
def decode_attention(q, k, v, kv_len, *, bk=512):
    return _dec.decode_attention(
        q, k, v, kv_len, bk=bk, interpret=_default_interpret()
    )


@functools.partial(jax.jit, static_argnames=("chunk", "bi"))
def ssm_scan(dA, dBx, C, h0, *, chunk=64, bi=512):
    return _ssm.ssm_scan(
        dA, dBx, C, h0, chunk=chunk, bi=bi, interpret=_default_interpret()
    )


@functools.partial(jax.jit, static_argnames=("k", "window"))
def lags_select(load_avg, credit, running_frac, runnable, k, *, window=1000):
    return _lags.lags_select(
        load_avg, credit, running_frac, runnable, k, window=window,
        interpret=_default_interpret(),
    )
