"""Pallas TPU flash attention (forward) with causal / sliding-window masks.

Grid: (B*H, n_q_blocks, n_kv_blocks); the KV dimension is the innermost
(sequential on TPU), carrying the online-softmax state (m, l, acc) in VMEM
scratch.  Block shapes are MXU-aligned (multiples of 128 on the contraction
and lane dims; q/k blocks default 128x128).  VMEM working set per step:
q (bq, D) + k,v (bk, D) + acc (bq, D) + scores (bq, bk) — about 260 KB at
bq=bk=128, D=128 in fp32, well inside the ~16 MB VMEM budget, leaving room
for double buffering.

Sliding-window blocks whose entire (q_block, kv_block) tile is masked are
still visited (grid is static); the mask zeroes their contribution.  The
ops.py wrapper skips fully-masked KV tails by shrinking the grid when a
window is set.

Validated in interpret mode against ``ref.flash_attention_ref`` over shape
and dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
               *, scale, causal, window, bq, bk, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, D)
    k = k_ref[0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (bq, bk)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    bq=128, bk=128, interpret=False):
    """q,k,v: (B, H, S, D) -> (B, H, S, D)."""
    B, H, S, D = q.shape
    scale = float(scale if scale is not None else 1.0 / (D ** 0.5))
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_kv = S // bq, S // bk
    qf = q.reshape(B * H, S, D)
    kf = k.reshape(B * H, S, D)
    vf = v.reshape(B * H, S, D)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv=n_kv,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, D)
