"""Pallas TPU kernel for the LAGS scheduler hot path (``pick_next_task``).

One scheduler tick over T tenant cgroups: PELT + Load Credit EMA updates
(elementwise, VPU) followed by selection of the k lowest-credit runnable
tenants — the vectorised analogue of the kernel's pick_next_task_fair walk,
serving the engine's admission scheduler at thousands-of-tenants scale.

Single-block kernel: the credit state for T <= 65536 tenants is ~1 MB and
fits VMEM whole, so selection is k iterations of masked argmin over a VMEM
vector (no HBM round-trips).  T is padded to a lane multiple (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.load_credit import DEFAULT_EMA_WINDOW, PELT_HALFLIFE_TICKS

SUB = 8  # sublane tile for (SUB, T/...) layout; row 0 carries data
INF = float("inf")


def _lags_kernel(load_ref, credit_ref, frac_ref, runnable_ref,
                 newload_ref, newcredit_ref, idx_ref,
                 *, k, pelt_y, alpha, T):
    load = load_ref[...]
    credit = credit_ref[...]
    frac = frac_ref[...]
    runnable = runnable_ref[...]

    new_load = pelt_y * load + (1.0 - pelt_y) * frac
    new_credit = (1.0 - alpha) * credit + alpha * new_load
    newload_ref[...] = new_load
    newcredit_ref[...] = new_credit

    lane = jax.lax.broadcasted_iota(jnp.int32, new_credit.shape, 1)
    valid = (runnable > 0.5) & (lane < T)
    # stable tie-break by index
    key0 = jnp.where(valid, new_credit + lane.astype(jnp.float32) * 1e-12, INF)

    def pick(i, key):
        m = jnp.min(key)
        # first index attaining the min
        is_min = key == m
        idx = jnp.min(jnp.where(is_min, lane, T + 1))
        idx_ref[0, i] = jnp.where(jnp.isfinite(m), idx, -1)
        return jnp.where(lane == idx, INF, key)

    jax.lax.fori_loop(0, k, pick, key0)


def lags_select(load_avg, credit, running_frac, runnable, k,
                *, window=DEFAULT_EMA_WINDOW,
                halflife=PELT_HALFLIFE_TICKS, interpret=False):
    """Vectorised scheduler tick.  All inputs (T,) float32/bool.

    Returns (new_load (T,), new_credit (T,), picked_idx (k,) int32 with -1
    padding when fewer than k tenants are runnable).
    """
    T = load_avg.shape[0]
    Tp = -(-T // 128) * 128
    pad = lambda x: jnp.pad(x.astype(jnp.float32), (0, Tp - T))[None, :]
    pelt_y = float(0.5 ** (1.0 / halflife))
    alpha = float(2.0 / (window + 1.0))

    kernel = functools.partial(
        _lags_kernel, k=k, pelt_y=pelt_y, alpha=alpha, T=T
    )
    nl, nc, idx = pl.pallas_call(
        kernel,
        in_specs=[
            pl.BlockSpec((1, Tp), lambda: (0, 0)),
            pl.BlockSpec((1, Tp), lambda: (0, 0)),
            pl.BlockSpec((1, Tp), lambda: (0, 0)),
            pl.BlockSpec((1, Tp), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Tp), lambda: (0, 0)),
            pl.BlockSpec((1, Tp), lambda: (0, 0)),
            pl.BlockSpec((1, k), lambda: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Tp), jnp.float32),
            jax.ShapeDtypeStruct((1, Tp), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.int32),
        ],
        interpret=interpret,
    )(
        pad(load_avg),
        pad(credit),
        pad(running_frac),
        pad(runnable.astype(jnp.float32)),
    )
    return nl[0, :T], nc[0, :T], idx[0]
