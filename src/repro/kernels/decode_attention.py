"""Pallas TPU flash-decode: one query token against a long KV cache.

Grid: (B*H, n_kv_blocks) — KV blocks sequential, online-softmax state in
VMEM scratch.  The query row is padded to 8 sublanes for TPU tiling; KV
blocks default to 512 tokens (VMEM: 2 * 512 * D * 4B = 512 KB at D=128).
``kv_len`` masks the valid cache prefix, so one compiled kernel serves any
current sequence length (the engine's paged cache re-packs pages into this
dense layout per batch lane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
SUB = 8  # TPU sublane padding for the single query row


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, scale, bk, n_kv):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (SUB, D) — row 0 is real
    k = k_ref[0].astype(jnp.float32)  # (bk, D)
    v = v_ref[0].astype(jnp.float32)
    kv_len = len_ref[0]

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (SUB, bk)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (SUB, bk), 1)
    s = jnp.where(k_pos < kv_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def decode_attention(q, k, v, kv_len, *, scale=None, bk=512, interpret=False):
    """q: (B, H, D); k,v: (B, H, L, D); kv_len: (B,) -> (B, H, D)."""
    B, H, L, D = k.shape
    scale = float(scale if scale is not None else 1.0 / (D ** 0.5))
    bk = min(bk, L)
    assert L % bk == 0, (L, bk)
    n_kv = L // bk
    qf = jnp.zeros((B * H, SUB, D), q.dtype).at[:, 0, :].set(
        q.reshape(B * H, D)
    )
    kf = k.reshape(B * H, L, D)
    vf = v.reshape(B * H, L, D)
    lens = jnp.repeat(kv_len.astype(jnp.int32), H).reshape(B * H)

    kernel = functools.partial(_dec_kernel, scale=scale, bk=bk, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_kv),
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, SUB, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, SUB, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, SUB, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qf, kf, vf)
    return out[:, 0, :].reshape(B, H, D)
