"""Pallas TPU selective-scan (Mamba-1 recurrence), chunked over time.

Grid: (B, n_chunks, n_channel_blocks) with the chunk axis sequential — the
SSM state h (bi, N) is carried across chunk iterations in VMEM scratch.
Within a chunk the recurrence is evaluated time-sequentially with a
``fori_loop`` over the chunk (the state-dim N=16 recurrence is a VPU
elementwise op; the chunk's inputs live in VMEM so the loop runs at
register/VMEM speed — the HBM-facing layout is what the blocking controls).

Channel blocking (bi, default 512) keeps the VMEM working set to
chunk * bi * N * 4B (= 2 MB at chunk=64, bi=512, N=16) plus the carried
state.  Validated against ``ref.ssm_scan_ref`` in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(dA_ref, dBx_ref, C_ref, h0_ref, y_ref, hout_ref, h_ref,
                *, chunk, n_chunks):
    ci = pl.program_id(2)  # chunk axis is innermost: sequential carry

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    dA = dA_ref[0].astype(jnp.float32)  # (chunk, bi, N)
    dBx = dBx_ref[0].astype(jnp.float32)
    C = C_ref[0].astype(jnp.float32)  # (chunk, N)

    def step(t, carry):
        h = carry
        h = dA[t] * h + dBx[t]  # (bi, N)
        y_t = jnp.sum(h * C[t][None, :], axis=1)  # (bi,)
        y_ref[0, t, :] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _finish():
        hout_ref[0] = h.astype(hout_ref.dtype)


def ssm_scan(dA, dBx, C, h0, *, chunk=64, bi=512, interpret=False):
    """dA,dBx: (B,S,I,N); C: (B,S,N); h0: (B,I,N) -> (y (B,S,I), h (B,I,N))."""
    B, S, I, N = dA.shape
    chunk = min(chunk, S)
    bi = min(bi, I)
    assert S % chunk == 0 and I % bi == 0, (S, chunk, I, bi)
    n_chunks = S // chunk
    n_ib = I // bi

    kernel = functools.partial(_ssm_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(B, n_ib, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, bi, N), lambda b, i, c: (b, c, i, 0)),
            pl.BlockSpec((1, chunk, bi, N), lambda b, i, c: (b, c, i, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, i, c: (b, c, 0)),
            pl.BlockSpec((1, bi, N), lambda b, i, c: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, bi), lambda b, i, c: (b, c, i)),
            pl.BlockSpec((1, bi, N), lambda b, i, c: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, I), dA.dtype),
            jax.ShapeDtypeStruct((B, I, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bi, N), jnp.float32)],
        interpret=interpret,
    )(dA, dBx, C, h0)
    return y, h_last
