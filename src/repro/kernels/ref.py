"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, scale=None):
    """q,k,v: (B, H, S, D) -> (B, H, S, D).  window=0 means global."""
    B, H, S, D = q.shape
    scale = scale or (1.0 / jnp.sqrt(D).astype(jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= ki <= qi
    if window:
        mask &= ki > qi - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


def decode_attention_ref(q, k, v, kv_len, *, scale=None):
    """q: (B, H, D); k,v: (B, H, L, D); kv_len: (B,) valid prefix length."""
    B, H, L, D = k.shape
    scale = scale or (1.0 / jnp.sqrt(D).astype(jnp.float32))
    s = jnp.einsum("bhd,bhld->bhl", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    valid = jnp.arange(L)[None, None, :] < kv_len[:, None, None]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhl,bhld->bhd", p.astype(v.dtype), v)


def ssm_scan_ref(dA, dBx, C, h0):
    """Selective-scan: h_t = dA_t * h_{t-1} + dBx_t; y_t = h_t . C_t.

    dA, dBx: (B, S, I, N); C: (B, S, N); h0: (B, I, N).
    Returns (y (B, S, I), h_last (B, I, N)).
    """

    def step(h, xs):
        a, bx, c = xs
        h = a * h + bx
        return h, jnp.einsum("bin,bn->bi", h, c)

    xs = (
        dA.transpose(1, 0, 2, 3),
        dBx.transpose(1, 0, 2, 3),
        C.transpose(1, 0, 2),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_last


def lags_select_ref(load_avg, credit, running_frac, runnable, k,
                    *, pelt_y=0.5 ** (1 / 8), window=1000):
    """One scheduler tick over T cgroups: PELT + Load Credit EMA update, then
    pick the k runnable groups with the LOWEST updated credit.

    Returns (new_load, new_credit, picked_idx (k,), picked_mask (T,)).
    Ties broken by index (stable).  This is pick_next_task_fair vectorised.
    """
    alpha = 2.0 / (window + 1.0)
    new_load = pelt_y * load_avg + (1 - pelt_y) * running_frac
    new_credit = (1 - alpha) * credit + alpha * new_load
    key = jnp.where(runnable, new_credit, jnp.inf)
    # stable tie-break by index
    T = key.shape[0]
    key2 = key + jnp.arange(T, dtype=key.dtype) * 1e-12
    neg, idx = jax.lax.top_k(-key2, k)
    picked_valid = jnp.isfinite(-neg)
    picked_idx = jnp.where(picked_valid, idx, -1)
    mask = jnp.zeros(T, bool).at[jnp.where(picked_valid, idx, 0)].set(
        picked_valid
    )
    return new_load, new_credit, picked_idx, mask
