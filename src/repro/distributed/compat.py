"""Version-compat shims for JAX distributed APIs.

``shard_map`` has moved twice across jax versions (``jax.experimental.
shard_map`` -> top-level ``jax.shard_map``) and renamed its replication-
check kwarg (``check_rep`` -> ``check_vma``).  Import it from here so call
sites and tests are pinned to one spelling regardless of the installed jax:

    from repro.distributed.compat import shard_map
"""
from __future__ import annotations

import inspect

_shard_map = None
_params = None


def _resolve():
    global _shard_map, _params
    if _shard_map is None:
        import jax

        fn = getattr(jax, "shard_map", None)
        if fn is None:  # jax <= 0.5.x
            from jax.experimental.shard_map import shard_map as fn
        _shard_map = fn
        _params = frozenset(inspect.signature(fn).parameters)
    return _shard_map


def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
    """Call the installed jax's shard_map, translating the replication-check
    kwarg (``check_vma``/``check_rep``) to whichever this version accepts."""
    fn = _resolve()
    for ours, theirs in (("check_vma", "check_rep"), ("check_rep", "check_vma")):
        if ours in kwargs and ours not in _params:
            kwargs[theirs] = kwargs.pop(ours)
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
