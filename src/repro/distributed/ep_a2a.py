"""Sort-based expert-parallel MoE dispatch via all_to_all (shard_map).

The production alternative to the GShard dense-dispatch einsums in
``repro.models.moe`` (EXPERIMENTS.md §Perf "dx"): tokens stay local to their
data shard, are bucketed by destination expert shard with a fixed per-peer
capacity, exchanged with a single ``lax.all_to_all`` over the "model" axis,
FFN'd by the local experts, and returned by the inverse exchange.  Wire
bytes are 2 * tokens * d_model * 2 B * capacity_factor — token payloads, not
one-hot products (napkin: qwen2-moe train ~8 GB/step vs ~500 GB for the
dense-dispatch gradient reductions).

This module provides the building blocks + a single-shard reference used by
tests; wiring it as ``ModelConfig.moe_impl="ep_a2a"`` across the stack is
the follow-on perf iteration (§Perf log).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bucket_by_peer(x, expert_ids, gate_w, n_peers: int, capacity: int):
    """Pack tokens into fixed-capacity per-peer send buffers.

    x: (T, M); expert_ids/gate_w: (T, K) global expert ids and gate weights;
    experts are owned block-wise: peer p owns experts [p*E/P, (p+1)*E/P).

    Returns (send_x (P, C, M), send_meta (P, C, 3) [src_slot, local_expert,
    gate*2^?? -> gate as float in meta_w], counts (P,)).  Overflow beyond
    ``capacity`` is dropped (capacity-factor semantics, as in the dense path).
    """
    T, K = expert_ids.shape
    E_per_peer = None  # implied by caller's id mapping
    flat_ids = expert_ids.reshape(-1)  # (T*K,)
    flat_gate = gate_w.reshape(-1)
    flat_src = jnp.repeat(jnp.arange(T), K)
    peer = flat_ids // jnp.maximum(1, (jnp.max(flat_ids) + 1) // n_peers)
    # stable sort by peer
    order = jnp.argsort(peer * (T * K) + jnp.arange(T * K))
    peer_s = peer[order]
    # position within peer bucket
    onehot = jax.nn.one_hot(peer_s, n_peers, dtype=jnp.int32)  # (TK, P)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.sum(pos * onehot, axis=1)  # (TK,)
    keep = slot < capacity
    dest = peer_s * capacity + jnp.where(keep, slot, capacity - 1)

    send_x = jnp.zeros((n_peers * capacity, x.shape[1]), x.dtype)
    send_x = send_x.at[dest].add(
        jnp.where(keep[:, None], x[flat_src[order]], 0)
    )
    meta_src = jnp.full((n_peers * capacity,), -1, jnp.int32).at[dest].set(
        jnp.where(keep, flat_src[order], -1)
    )
    meta_eid = jnp.zeros((n_peers * capacity,), jnp.int32).at[dest].set(
        jnp.where(keep, flat_ids[order], 0)
    )
    meta_gate = jnp.zeros((n_peers * capacity,)).at[dest].set(
        jnp.where(keep, flat_gate[order], 0.0)
    )
    counts = jnp.sum(onehot * keep[:, None], axis=0)
    return (
        send_x.reshape(n_peers, capacity, x.shape[1]),
        meta_src.reshape(n_peers, capacity),
        meta_eid.reshape(n_peers, capacity),
        meta_gate.reshape(n_peers, capacity),
        counts,
    )


def expert_ffn(xs, eids_local, w_gate, w_up, w_down):
    """Apply the owning shard's experts.  xs: (N, M); eids_local: (N,)
    local expert index; w_*: (E_local, M, F) / (E_local, F, M)."""
    wg = w_gate[eids_local]  # (N, M, F)
    wu = w_up[eids_local]
    wd = w_down[eids_local]
    g = jnp.einsum("nm,nmf->nf", xs, wg)
    u = jnp.einsum("nm,nmf->nf", xs, wu)
    return jnp.einsum("nf,nfm->nm", jax.nn.silu(g) * u, wd)


def moe_ep_a2a_local(x, expert_ids, gate_w, w_gate, w_up, w_down,
                     axis_name: str | None = None,
                     capacity_factor: float = 1.25):
    """One data-shard's MoE via bucketed exchange.

    When ``axis_name`` is set (inside shard_map over the "model" axis), the
    buffers cross shards via lax.all_to_all; with ``axis_name=None`` this is
    the single-shard reference (peers = 1), numerically identical to the
    capacity-limited dense path and used as the test oracle.
    """
    T, M = x.shape
    K = expert_ids.shape[1]
    n_peers = (
        jax.lax.psum(1, axis_name) if axis_name is not None else 1
    )
    capacity = max(1, int(T * K * capacity_factor / max(n_peers, 1)))
    send_x, m_src, m_eid, m_gate, _ = bucket_by_peer(
        x, expert_ids, gate_w, n_peers, capacity
    )
    if axis_name is not None:
        recv_x = jax.lax.all_to_all(send_x, axis_name, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(m_eid, axis_name, 0, 0, tiled=False)
    else:
        recv_x, recv_eid = send_x, m_eid
    E_local = w_gate.shape[0]
    flat_x = recv_x.reshape(-1, M)
    local_eid = recv_eid.reshape(-1) % E_local
    out = expert_ffn(flat_x, local_eid, w_gate, w_up, w_down)
    out = out.reshape(recv_x.shape)
    if axis_name is not None:
        out = jax.lax.all_to_all(out, axis_name, 0, 0, tiled=False)
    # combine back to source slots with gate weights
    y = jnp.zeros((T, M), x.dtype)
    flat_out = out.reshape(-1, M)
    flat_src = m_src.reshape(-1)
    flat_gate = m_gate.reshape(-1)
    ok = flat_src >= 0
    y = y.at[jnp.where(ok, flat_src, 0)].add(
        jnp.where(ok[:, None], flat_out * flat_gate[:, None], 0.0)
    )
    return y
