"""Fault tolerance & elasticity control plane (host-level logic).

On a real cluster these hooks run in the launcher process per host; here the
logic is pure and unit-tested with virtual hosts:

  * ``HealthTracker`` — heartbeat bookkeeping with an evidence-based
    failure ladder: SUSPECT (heartbeats overdue) is distinct from
    CONFIRMED-DEAD (heartbeats overdue *and* no observed progress), so a
    partitioned-but-alive host is fenced rather than declared failed;
  * ``plan_remesh`` — given surviving hosts, pick the largest valid
    (pod, data, model) mesh <= survivors and the checkpoint-resume plan
    (elastic rescale via ``checkpoint.restore(..., sharding_tree)``);
  * ``StragglerWatchdog`` — step-time EWMA; flags hosts slower than
    ``k`` sigma for hot-spare replacement (straggler mitigation);
  * ``TrendDetector`` — hysteresis band over a per-host observable vs the
    healthy-fleet mean; flags hosts *trending* degraded (for proactive
    drain) and never flaps: a host enters draining above ``enter_ratio``
    (debounced) and leaves only below the lower ``exit_ratio``;
  * preemption-safe training is provided by atomic checkpoints
    (``repro.train.checkpoint``) + deterministic data (``repro.train.data``):
    restart = restore(latest) and continue at the stored step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class HealthTracker:
    """Heartbeat bookkeeping with a SUSPECT tier between healthy and failed.

    Heartbeats ride the network; progress observations come from a second
    channel (the controller *sees* completed work in sim results or shared
    storage).  A host whose heartbeats stopped but whose work keeps
    landing is partitioned/delayed, not dead — conflating the two
    double-places its functions.  The ladder:

      healthy  — heartbeat within ``timeout_s``;
      SUSPECT  — heartbeat overdue, but progress observed recently (or
                 never confirmed dead): fence it — route no new work,
                 let in-flight work complete, reconcile on heal;
      failed   — heartbeat overdue AND progress stale too.  Hosts that
                 never produced a progress observation fall back to the
                 heartbeat-only verdict (the pre-SUSPECT behaviour, so
                 plain crash detection keeps its exact timing).
    """

    n_hosts: int
    timeout_s: float = 60.0
    # a freshly registered host gets this long to send its *first* heartbeat
    # before it can be declared failed (it used to be failed from t=0: the
    # old ``last_seen`` default of -1e18 made every never-heartbeated host
    # exceed the timeout immediately).  ``None`` means "same as timeout_s".
    grace_s: Optional[float] = None
    # staleness horizon for progress evidence; ``None`` = same as timeout_s
    progress_timeout_s: Optional[float] = None
    last_seen: Dict[int, float] = field(default_factory=dict)
    registered_at: Dict[int, float] = field(default_factory=dict)
    last_progress: Dict[int, float] = field(default_factory=dict)
    last_routed: Dict[int, float] = field(default_factory=dict)

    def register(self, host: int, now: Optional[float] = None):
        """Start the grace window for a host that has not heartbeated yet."""
        self.registered_at[host] = time.monotonic() if now is None else now

    def heartbeat(self, host: int, now: Optional[float] = None):
        self.last_seen[host] = time.monotonic() if now is None else now

    def observe_progress(self, host: int, now: Optional[float] = None):
        """Record out-of-band evidence the host is doing work (completions
        observed in sim results / shared storage) — independent of the
        heartbeat network, so it survives partitions and delays."""
        self.last_progress[host] = time.monotonic() if now is None else now

    def note_routed(self, host: int, now: Optional[float] = None):
        """Record that the controller routed work to this host (and has
        thus *earned* the right to expect progress).  Without it, fencing
        a suspect would starve its progress channel and the silence — the
        controller's own doing — would escalate a live partitioned host
        to CONFIRMED-DEAD."""
        self.last_routed[host] = time.monotonic() if now is None else now

    def _hb_overdue(self, host: int, now: float) -> bool:
        grace = self.timeout_s if self.grace_s is None else self.grace_s
        seen = self.last_seen.get(host)
        if seen is not None:
            return now - seen > self.timeout_s
        # never heartbeated: overdue only once the registration grace
        # expires (unregistered hosts date from t=0)
        return now - self.registered_at.get(host, 0.0) > grace

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        """CONFIRMED-DEAD hosts: heartbeat overdue and, when the host has
        ever shown progress, that evidence is stale as well.  When work
        routing is tracked (``note_routed``), stale progress only damns a
        host that was handed work *after* its last observed progress — a
        host that answered everything it was ever given and then received
        nothing (because the controller fenced it) stays SUSPECT."""
        now = time.monotonic() if now is None else now
        pt = (self.timeout_s if self.progress_timeout_s is None
              else self.progress_timeout_s)
        out = []
        for h in range(self.n_hosts):
            if not self._hb_overdue(h, now):
                continue
            prog = self.last_progress.get(h)
            if prog is None:  # never progressed: heartbeat-only fallback
                out.append(h)
                continue
            if now - prog <= pt:
                continue
            routed = self.last_routed.get(h)
            if routed is None or routed > prog:
                out.append(h)
        return out

    def suspect_hosts(self, now: Optional[float] = None) -> List[int]:
        """Hosts whose heartbeats are overdue but that are *not* confirmed
        dead — recent progress contradicts the silence.  These should be
        fenced (no new arrivals) rather than failed over."""
        now = time.monotonic() if now is None else now
        dead = set(self.failed_hosts(now))
        return [h for h in range(self.n_hosts)
                if h not in dead and self._hb_overdue(h, now)]

    def healthy_hosts(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.failed_hosts(now))
        return [h for h in range(self.n_hosts) if h not in bad]


def plan_remesh(
    n_healthy_chips: int,
    model_parallel: int = 16,
    prefer_pods: int = 2,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) mesh fitting the surviving chips.

    Model parallelism is preserved (weights shard layout unchanged); data
    parallelism shrinks — batch is re-spread and optimizer state re-sharded
    from the checkpoint.  Examples: 512 chips -> (2,16,16); lose a host of
    8 chips -> 504 chips -> (1,31,16) = 496 used.
    """
    if n_healthy_chips < model_parallel:
        raise ValueError("fewer chips than model-parallel degree")
    groups = n_healthy_chips // model_parallel
    for pods in range(min(prefer_pods, groups), 0, -1):
        if groups % pods == 0:
            data = groups // pods
            if pods > 1:
                return (pods, data, model_parallel), ("pod", "data", "model")
            return (data, model_parallel), ("data", "model")
    return (groups, model_parallel), ("data", "model")


@dataclass
class StragglerWatchdog:
    """Flags hosts whose step time exceeds mean + k*sigma (EWMA)."""

    n_hosts: int
    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup: int = 8
    # a host is flagged only when its step time ALSO exceeds this multiple
    # of the fleet mean: the k-sigma test alone misfires on heterogeneous
    # fleets (per-host EWMA variance can be tiny while host means honestly
    # differ by tens of percent), and one false flag drains a healthy host
    min_ratio: float = 2.0
    # weight applied to the EWMA update of a sample that was *flagged* as a
    # straggler.  Flagged samples used to feed back at full weight into the
    # host's own mean/var (and hence the fleet mean), so a persistent 3x
    # straggler raised its own baseline until it looked normal again; 0.0
    # excludes flagged samples entirely, small values down-weight them.
    flagged_weight: float = 0.0
    # consecutive suspect observations required before ``observe`` reports
    # a straggler.  A single sample cannot separate a genuinely slow host
    # from a transient spike in the observable (e.g. an epoched observer's
    # busy/completed ratio right after a burst leaves censored in-flight
    # work) — a real slowdown persists, a spike does not, and one false
    # flag drains a healthy host.
    persist: int = 2
    mean: Dict[int, float] = field(default_factory=dict)
    var: Dict[int, float] = field(default_factory=dict)
    count: Dict[int, int] = field(default_factory=dict)
    streak: Dict[int, int] = field(default_factory=dict)

    def observe(self, host: int, step_s: float) -> bool:
        """Record a step time; returns True if host is now a straggler."""
        m = self.mean.get(host, step_s)
        v = self.var.get(host, 0.0)
        self.count[host] = self.count.get(host, 0) + 1
        suspect = False
        if self.count[host] > self.warmup:
            sigma = max(v, 1e-12) ** 0.5
            fleet_mean = sum(self.mean.values()) / max(len(self.mean), 1)
            if (step_s > fleet_mean * self.min_ratio
                    and step_s > fleet_mean
                    + self.k_sigma * max(sigma, 0.05 * fleet_mean)):
                suspect = True
        # suspect samples stay out of the baseline even while debouncing,
        # else a real straggler would normalise itself before persisting
        a = self.alpha * (self.flagged_weight if suspect else 1.0)
        d = step_s - m
        self.mean[host] = m + a * d
        self.var[host] = (1 - a) * (v + a * d * d)
        self.streak[host] = self.streak.get(host, 0) + 1 if suspect else 0
        return suspect and self.streak[host] >= self.persist


@dataclass
class TrendDetector:
    """Flags hosts *trending* degraded, with hysteresis so it never flaps.

    The ``StragglerWatchdog`` answers "is this host an outlier right
    now?"; proactive draining needs the earlier, stickier question "is
    this host's per-request service time drifting away from the fleet,
    and has it stayed there?".  Each host keeps an EWMA of its observable
    (e.g. busy seconds per completed request) that is compared against
    the mean EWMA of the *non-draining* hosts:

      * a host enters the draining set once its ratio has exceeded
        ``enter_ratio`` for ``persist`` consecutive observations (a
        single burst does not trigger a migration storm);
      * it leaves only once the ratio drops below ``exit_ratio`` —
        with ``exit_ratio < enter_ratio`` the band between the two is
        dead zone in both directions, so a host oscillating around the
        threshold cannot flap in and out of draining.
    """

    n_hosts: int
    alpha: float = 0.35
    enter_ratio: float = 1.6
    exit_ratio: float = 1.2
    persist: int = 2
    warmup: int = 2
    ewma: Dict[int, float] = field(default_factory=dict)
    count: Dict[int, int] = field(default_factory=dict)
    streak: Dict[int, int] = field(default_factory=dict)
    draining: Dict[int, bool] = field(default_factory=dict)

    def __post_init__(self):
        if not (0.0 < self.exit_ratio <= self.enter_ratio):
            raise ValueError(
                f"need 0 < exit_ratio <= enter_ratio for hysteresis, got "
                f"exit={self.exit_ratio} enter={self.enter_ratio}")

    def _fleet_mean(self, exclude: int) -> float:
        # baseline = healthy (non-draining) hosts, so a degraded host's own
        # EWMA cannot drag the fleet mean up and mask itself; the observed
        # host is excluded from its own baseline
        vals = [v for h, v in self.ewma.items()
                if h != exclude and not self.draining.get(h, False)]
        if not vals:  # everyone else drains: fall back to all other hosts
            vals = [v for h, v in self.ewma.items() if h != exclude]
        return sum(vals) / len(vals) if vals else 0.0

    def observe(self, host: int, value: float) -> bool:
        """Record one observation; returns True while ``host`` should be
        draining (new work steered away, load migrated off)."""
        m = self.ewma.get(host, value)
        self.ewma[host] = m + self.alpha * (value - m)
        self.count[host] = self.count.get(host, 0) + 1
        fleet = self._fleet_mean(host)
        if self.count[host] <= self.warmup or fleet <= 0.0:
            self.streak[host] = 0
            return self.draining.get(host, False)
        ratio = self.ewma[host] / fleet
        if self.draining.get(host, False):
            if ratio < self.exit_ratio:
                self.draining[host] = False
                self.streak[host] = 0
        else:
            if ratio > self.enter_ratio:
                self.streak[host] = self.streak.get(host, 0) + 1
                if self.streak[host] >= self.persist:
                    self.draining[host] = True
            else:
                self.streak[host] = 0
        return self.draining.get(host, False)

    def drain_hosts(self) -> List[int]:
        return sorted(h for h, d in self.draining.items() if d)

    def forget(self, host: int):
        """Drop a host's state (it crashed or was replaced — its history
        must not poison the baseline when a fresh node takes the slot)."""
        for d in (self.ewma, self.count, self.streak, self.draining):
            d.pop(host, None)
