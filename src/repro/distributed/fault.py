"""Fault tolerance & elasticity control plane (host-level logic).

On a real cluster these hooks run in the launcher process per host; here the
logic is pure and unit-tested with virtual hosts:

  * ``HealthTracker`` — heartbeat bookkeeping, failure detection by timeout;
  * ``plan_remesh`` — given surviving hosts, pick the largest valid
    (pod, data, model) mesh <= survivors and the checkpoint-resume plan
    (elastic rescale via ``checkpoint.restore(..., sharding_tree)``);
  * ``StragglerWatchdog`` — step-time EWMA; flags hosts slower than
    ``k`` sigma for hot-spare replacement (straggler mitigation);
  * preemption-safe training is provided by atomic checkpoints
    (``repro.train.checkpoint``) + deterministic data (``repro.train.data``):
    restart = restore(latest) and continue at the stored step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class HealthTracker:
    n_hosts: int
    timeout_s: float = 60.0
    # a freshly registered host gets this long to send its *first* heartbeat
    # before it can be declared failed (it used to be failed from t=0: the
    # old ``last_seen`` default of -1e18 made every never-heartbeated host
    # exceed the timeout immediately).  ``None`` means "same as timeout_s".
    grace_s: Optional[float] = None
    last_seen: Dict[int, float] = field(default_factory=dict)
    registered_at: Dict[int, float] = field(default_factory=dict)

    def register(self, host: int, now: Optional[float] = None):
        """Start the grace window for a host that has not heartbeated yet."""
        self.registered_at[host] = time.monotonic() if now is None else now

    def heartbeat(self, host: int, now: Optional[float] = None):
        self.last_seen[host] = time.monotonic() if now is None else now

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        grace = self.timeout_s if self.grace_s is None else self.grace_s
        out = []
        for h in range(self.n_hosts):
            seen = self.last_seen.get(h)
            if seen is not None:
                if now - seen > self.timeout_s:
                    out.append(h)
            else:
                # never heartbeated: failed only once the registration grace
                # expires (unregistered hosts date from t=0)
                if now - self.registered_at.get(h, 0.0) > grace:
                    out.append(h)
        return out

    def healthy_hosts(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.failed_hosts(now))
        return [h for h in range(self.n_hosts) if h not in bad]


def plan_remesh(
    n_healthy_chips: int,
    model_parallel: int = 16,
    prefer_pods: int = 2,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) mesh fitting the surviving chips.

    Model parallelism is preserved (weights shard layout unchanged); data
    parallelism shrinks — batch is re-spread and optimizer state re-sharded
    from the checkpoint.  Examples: 512 chips -> (2,16,16); lose a host of
    8 chips -> 504 chips -> (1,31,16) = 496 used.
    """
    if n_healthy_chips < model_parallel:
        raise ValueError("fewer chips than model-parallel degree")
    groups = n_healthy_chips // model_parallel
    for pods in range(min(prefer_pods, groups), 0, -1):
        if groups % pods == 0:
            data = groups // pods
            if pods > 1:
                return (pods, data, model_parallel), ("pod", "data", "model")
            return (data, model_parallel), ("data", "model")
    return (groups, model_parallel), ("data", "model")


@dataclass
class StragglerWatchdog:
    """Flags hosts whose step time exceeds mean + k*sigma (EWMA)."""

    n_hosts: int
    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup: int = 8
    # a host is flagged only when its step time ALSO exceeds this multiple
    # of the fleet mean: the k-sigma test alone misfires on heterogeneous
    # fleets (per-host EWMA variance can be tiny while host means honestly
    # differ by tens of percent), and one false flag drains a healthy host
    min_ratio: float = 2.0
    # weight applied to the EWMA update of a sample that was *flagged* as a
    # straggler.  Flagged samples used to feed back at full weight into the
    # host's own mean/var (and hence the fleet mean), so a persistent 3x
    # straggler raised its own baseline until it looked normal again; 0.0
    # excludes flagged samples entirely, small values down-weight them.
    flagged_weight: float = 0.0
    # consecutive suspect observations required before ``observe`` reports
    # a straggler.  A single sample cannot separate a genuinely slow host
    # from a transient spike in the observable (e.g. an epoched observer's
    # busy/completed ratio right after a burst leaves censored in-flight
    # work) — a real slowdown persists, a spike does not, and one false
    # flag drains a healthy host.
    persist: int = 2
    mean: Dict[int, float] = field(default_factory=dict)
    var: Dict[int, float] = field(default_factory=dict)
    count: Dict[int, int] = field(default_factory=dict)
    streak: Dict[int, int] = field(default_factory=dict)

    def observe(self, host: int, step_s: float) -> bool:
        """Record a step time; returns True if host is now a straggler."""
        m = self.mean.get(host, step_s)
        v = self.var.get(host, 0.0)
        self.count[host] = self.count.get(host, 0) + 1
        suspect = False
        if self.count[host] > self.warmup:
            sigma = max(v, 1e-12) ** 0.5
            fleet_mean = sum(self.mean.values()) / max(len(self.mean), 1)
            if (step_s > fleet_mean * self.min_ratio
                    and step_s > fleet_mean
                    + self.k_sigma * max(sigma, 0.05 * fleet_mean)):
                suspect = True
        # suspect samples stay out of the baseline even while debouncing,
        # else a real straggler would normalise itself before persisting
        a = self.alpha * (self.flagged_weight if suspect else 1.0)
        d = step_s - m
        self.mean[host] = m + a * d
        self.var[host] = (1 - a) * (v + a * d * d)
        self.streak[host] = self.streak.get(host, 0) + 1 if suspect else 0
        return suspect and self.streak[host] >= self.persist
