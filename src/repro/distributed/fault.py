"""Fault tolerance & elasticity control plane (host-level logic).

On a real cluster these hooks run in the launcher process per host; here the
logic is pure and unit-tested with virtual hosts:

  * ``HealthTracker`` — heartbeat bookkeeping, failure detection by timeout;
  * ``plan_remesh`` — given surviving hosts, pick the largest valid
    (pod, data, model) mesh <= survivors and the checkpoint-resume plan
    (elastic rescale via ``checkpoint.restore(..., sharding_tree)``);
  * ``StragglerWatchdog`` — step-time EWMA; flags hosts slower than
    ``k`` sigma for hot-spare replacement (straggler mitigation);
  * preemption-safe training is provided by atomic checkpoints
    (``repro.train.checkpoint``) + deterministic data (``repro.train.data``):
    restart = restore(latest) and continue at the stored step.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class HealthTracker:
    n_hosts: int
    timeout_s: float = 60.0
    last_seen: Dict[int, float] = field(default_factory=dict)

    def heartbeat(self, host: int, now: Optional[float] = None):
        self.last_seen[host] = time.monotonic() if now is None else now

    def failed_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [
            h
            for h in range(self.n_hosts)
            if now - self.last_seen.get(h, -1e18) > self.timeout_s
        ]

    def healthy_hosts(self, now: Optional[float] = None) -> List[int]:
        bad = set(self.failed_hosts(now))
        return [h for h in range(self.n_hosts) if h not in bad]


def plan_remesh(
    n_healthy_chips: int,
    model_parallel: int = 16,
    prefer_pods: int = 2,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Largest (pod, data, model) mesh fitting the surviving chips.

    Model parallelism is preserved (weights shard layout unchanged); data
    parallelism shrinks — batch is re-spread and optimizer state re-sharded
    from the checkpoint.  Examples: 512 chips -> (2,16,16); lose a host of
    8 chips -> 504 chips -> (1,31,16) = 496 used.
    """
    if n_healthy_chips < model_parallel:
        raise ValueError("fewer chips than model-parallel degree")
    groups = n_healthy_chips // model_parallel
    for pods in range(min(prefer_pods, groups), 0, -1):
        if groups % pods == 0:
            data = groups // pods
            if pods > 1:
                return (pods, data, model_parallel), ("pod", "data", "model")
            return (data, model_parallel), ("data", "model")
    return (groups, model_parallel), ("data", "model")


@dataclass
class StragglerWatchdog:
    """Flags hosts whose step time exceeds mean + k*sigma (EWMA)."""

    n_hosts: int
    alpha: float = 0.1
    k_sigma: float = 3.0
    warmup: int = 8
    mean: Dict[int, float] = field(default_factory=dict)
    var: Dict[int, float] = field(default_factory=dict)
    count: Dict[int, int] = field(default_factory=dict)

    def observe(self, host: int, step_s: float) -> bool:
        """Record a step time; returns True if host is now a straggler."""
        m = self.mean.get(host, step_s)
        v = self.var.get(host, 0.0)
        self.count[host] = self.count.get(host, 0) + 1
        is_straggler = False
        if self.count[host] > self.warmup:
            sigma = max(v, 1e-12) ** 0.5
            fleet_mean = sum(self.mean.values()) / max(len(self.mean), 1)
            if step_s > fleet_mean + self.k_sigma * max(sigma, 0.05 * fleet_mean):
                is_straggler = True
        d = step_s - m
        self.mean[host] = m + self.alpha * d
        self.var[host] = (1 - self.alpha) * (v + self.alpha * d * d)
        return is_straggler
