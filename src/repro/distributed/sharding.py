"""Logical-axis sharding rules (MaxText-style) and helpers.

Every parameter and strategic activation carries *logical* axis names
("batch", "heads", "embed", "experts", ...).  A rule table maps logical names
to physical mesh axes; :func:`to_pspec` resolves them, dropping physical axes
that are absent from the active mesh (so the same model code runs on a single
CPU device, a 16x16 pod, or a 2x16x16 multi-pod mesh).

Two rule presets are provided: ``TRAIN_RULES`` (FSDP over "data" + TP over
"model") and ``DECODE_RULES`` (adds KV-sequence parallelism over "model" for
long-context decode).  The hillclimbing variants in EXPERIMENTS.md §Perf swap
individual rules, not model code.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> physical mesh axis (or tuple of axes)
TRAIN_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "model",  # Megatron-style sequence parallelism on the residual
    "kv_seq": None,
    "heads": "model",
    "kv_heads": "model",
    "qk_features": "model",  # fused head*dim projections
    "embed": None,  # activation embed dim replicated
    "mlp": "model",
    "experts": "model",
    # falls back to "model" when the expert count is not mesh-divisible
    # (e.g. qwen2-moe's 60 experts): the used-axis tracking in to_pspec
    # gives "experts" first claim on the axis when divisible.
    "expert_mlp": "model",
    "vocab": "model",
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    "dt_rank": None,
    # parameter-only axes (FSDP dimension)
    "embed_p": "data",
    "capacity": None,
}

# Long-context decode: batch is tiny, KV length is huge -> shard KV sequence;
# a single new token cannot be sequence-parallel.  Serving holds no optimizer
# state, so weights are NOT FSDP-sharded over "data" (replicating them kills
# the per-step all-gathers that dominated the baseline decode roofline —
# EXPERIMENTS.md §Perf H1); expert FFN dims shard over "data" instead so MoE
# giants still fit (qwen3-moe: 1.8 GB/chip expert weights, token-sized
# routing comm instead of 57 GB/step weight gathers).
DECODE_RULES = dict(
    TRAIN_RULES,
    kv_seq="model",
    seq_sp=None,
    batch=("pod", "data"),
    embed_p=None,
    expert_mlp="data",
)


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: dict = TRAIN_RULES


_CTX = _Ctx()


@contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = rules if rules is not None else TRAIN_RULES
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def to_pspec(
    logical,
    rules: Optional[dict] = None,
    mesh: Optional[Mesh] = None,
    shape: Optional[tuple] = None,
) -> P:
    """Resolve logical axis names to a PartitionSpec for the active mesh.

    Shape-aware: a mapping is dropped when the dimension is not divisible by
    the product of the mapped mesh axis sizes (pjit in_shardings require
    exact divisibility), and when a mesh axis was already consumed by an
    earlier dimension (PartitionSpecs may use each axis once).
    """
    rules = rules if rules is not None else _CTX.rules
    mesh = mesh if mesh is not None else _CTX.mesh
    mesh_axes = set(mesh.axis_names) if mesh is not None else None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}
    used: set = set()
    out = []
    for i, name in enumerate(logical):
        if name is None:
            out.append(None)
            continue
        phys = rules.get(name, None)
        if phys is None:
            out.append(None)
            continue
        if isinstance(phys, str):
            phys = (phys,)
        if mesh_axes is not None:
            phys = tuple(a for a in phys if a in mesh_axes and a not in used)
        if shape is not None and phys:
            # greedily keep the longest prefix that divides the dimension
            while phys:
                prod = 1
                for a in phys:
                    prod *= sizes.get(a, 1)
                if shape[i] % prod == 0:
                    break
                phys = phys[:-1]
        if not phys:
            out.append(None)
            continue
        used.update(phys)
        out.append(phys[0] if len(phys) == 1 else tuple(phys))
    return P(*out)


def constrain(x, *logical):
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = _CTX.mesh
    if mesh is None:
        return x
    spec = to_pspec(logical, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(logical, mesh: Optional[Mesh] = None, rules=None):
    mesh = mesh if mesh is not None else _CTX.mesh
    assert mesh is not None
    return NamedSharding(mesh, to_pspec(logical, rules=rules, mesh=mesh))
