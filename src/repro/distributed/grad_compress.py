"""Gradient compression for cross-pod data parallelism.

int8 quantisation with per-tensor scale and error feedback (residual carried
between steps).  Two entry points:

  * ``compress``/``decompress`` — numerics-faithful pair used inside the
    train step when ``TrainConfig.compress_grads`` is set; models exactly
    what the wire sees (int8 payload + fp32 scale).
  * ``compressed_psum`` — the production collective for the pod axis inside
    ``shard_map``: quantise, ``psum`` the int8 payload (as int32 accumulator
    to avoid overflow across pods), dequantise.  Cross-pod DCN/ICI bytes drop
    4x vs fp32 (2x vs bf16) at <0.1% relative error (tests assert this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g, bits: int = 8):
    """Returns (payload int8, scale fp32)."""
    absmax = jnp.max(jnp.abs(g.astype(jnp.float32)))
    qmax = 2.0 ** (bits - 1) - 1.0
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale


def decompress(q, scale, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(g, residual, bits: int = 8):
    """Error-feedback compression: returns (payload, scale, new_residual)."""
    g32 = g.astype(jnp.float32) + residual
    q, scale = compress(g32, bits)
    deq = decompress(q, scale)
    return q, scale, g32 - deq


def compressed_psum(g, axis_name: str, bits: int = 8):
    """Quantised all-reduce over ``axis_name`` (use inside shard_map).

    All shards agree on a shared scale (scalar pmax), then psum an int16
    payload (int8 quantisation, 16-bit accumulator: exact for <= 256 pods).
    Wire bytes: 2 per element vs 4 for fp32.  Returns the fp32 mean.
    """
    qmax = 2.0 ** (bits - 1) - 1.0
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -qmax, qmax)
    total = jax.lax.psum(q.astype(jnp.int16), axis_name)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    return total.astype(jnp.float32) * scale / n


def topk_compress(g, frac: float = 0.01):
    """Deep-Gradient-Compression-style sparsification: keep top ``frac`` of
    entries by magnitude.  Returns (values, flat_indices); pair with error
    feedback so dropped mass is carried to the next step."""
    flat = g.astype(jnp.float32).reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_decompress(values, idx, shape):
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    return flat.at[idx].add(values).reshape(shape)


def sparse_psum(g, axis_name: str, frac: float = 0.01):
    """Top-k sparse gradient exchange over ``axis_name`` (inside shard_map):
    each shard contributes its top-k (value, index) pairs via all_gather and
    the union is summed locally.  Wire bytes ~ 8 * frac * n vs 4 * n fp32 —
    a ~50x reduction at frac=1%."""
    vals, idx = topk_compress(g, frac)
    all_vals = jax.lax.all_gather(vals, axis_name)  # (P, k)
    all_idx = jax.lax.all_gather(idx, axis_name)
    n = jax.lax.psum(jnp.ones(()), axis_name)
    flat = jnp.zeros(g.size, jnp.float32)
    flat = flat.at[all_idx.reshape(-1)].add(all_vals.reshape(-1))
    return (flat / n).reshape(g.shape)
