"""Cluster consolidation study over the fleet layer (paper §5.1, Fig 7).

A cluster of identical 12-HT worker nodes hosts ~800 function containers
(Azure-2019 downscaled).  Baseline static reservation needs ``base_nodes``
nodes to meet peak demand; we consolidate the same workload onto fewer
nodes — under a chosen placement strategy — and find the smallest count
per policy that preserves the SLO.

The paper's headline: CFS needs 14 nodes; CFS-LAGS holds the same latency
distribution on 10 (-28 %), raising safe utilisation from ~45 % to ~55 %.

Calibration (``CLUSTER_EXEC_S``): the band rates in ``core.traces`` are
normalised for ~100 ms executions; the legacy cluster mode doubled the
execution time to 0.2 s *without* compensating, which doubled the offered
load — the 14-node static-reservation baseline ran at ~57 % utilisation
(the paper anchors it at ~45 %) and the cluster saturated on raw demand
below 12 nodes, so no scheduling policy could reach the paper's 10-node
point.  Cluster-mode requests are therefore 140 ms here, which lands the
measured utilisation curve on the paper's anchors: ~52 % at 14 nodes
rising to ~67 % at 10.  The sweep horizon is 60 s (``CLUSTER_DURATION_S``)
so burst backlogs drain inside the window — at 30 s up to a third of
arrivals were still queued at sim end and the percentiles were censored.

The SLO (:func:`min_nodes_meeting_slo`) is a burst-recovery budget against
the over-provisioned reference at max node count: the consolidated cluster
must complete ≥99 % of invocations, hold the median, and keep the p95
within ``tail_factor`` (1.4x) of the reference tail.

This module hosts the search itself (``benchmarks/fig7_cluster.py`` is a
thin driver over it) plus the per-node imbalance report; the simulation
and placement mechanics live in :mod:`repro.fleet.simulate` and
:mod:`repro.fleet.placement`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import numpy as np

from repro.fleet.placement import place
from repro.fleet.simulate import FleetResult, simulate_fleet
from repro.sched.numpy_backend import make_policy

CLUSTER_EXEC_S = 0.14  # paper-anchored calibration, see module docstring
CLUSTER_DURATION_S = 60.0  # burst backlogs must drain inside the window


@dataclass
class ClusterResult:
    policy: str
    n_nodes: int
    p50: float
    p95: float
    thr_slo: float
    util_effective: float
    util_perceived: float
    overhead_frac: float
    placement: str = "round-robin"
    p95_spread: float = 0.0  # per-node p95 max - min (imbalance)
    ovh_max_over_mean: float = 1.0  # overhead-fraction imbalance
    done_ratio: float = 1.0  # completions / arrivals within the horizon


def cluster_result(fleet: FleetResult, slo_s: float = 1.0) -> ClusterResult:
    imb = fleet.imbalance()
    return ClusterResult(
        policy=fleet.policy,
        n_nodes=fleet.n_nodes,
        p50=fleet.pct(50),
        p95=fleet.pct(95),
        thr_slo=fleet.throughput_slo(slo_s),
        util_effective=fleet.util_effective,
        util_perceived=fleet.util_perceived,
        overhead_frac=fleet.overhead_frac,
        placement=fleet.placement,
        p95_spread=imb["p95_spread"],
        ovh_max_over_mean=imb["ovh_max_over_mean"],
        done_ratio=fleet.n_completed / max(fleet.n_arrived, 1),
    )


def consolidation_sweep(
    total_fns: int = 800,
    node_counts: Sequence[int] = (15, 14, 12, 11, 10, 9, 8),
    policies: Sequence[str] = ("cfs", "lags"),
    duration_s: float = CLUSTER_DURATION_S,
    slo_s: float = 1.0,
    backend: str = "numpy",
    placement: str = "round-robin",
    n_cores: int = 12,
    seed: int = 7,
    distinct_seeds: bool = False,
    exec_s: float = CLUSTER_EXEC_S,
) -> List[ClusterResult]:
    """One fleet simulation per (policy, n_nodes) configuration."""
    out = []
    for pol in policies:
        for n in node_counts:
            asg = place(placement, total_fns, n, n_cores=n_cores,
                        policy=make_policy(pol), exec_s=exec_s, seed=seed)
            fleet = simulate_fleet(
                pol, asg, duration_s=duration_s, n_cores=n_cores, seed=seed,
                exec_s=exec_s, backend=backend,
                distinct_seeds=distinct_seeds,
            )
            out.append(cluster_result(fleet, slo_s))
    return out


def min_nodes_meeting_slo(
    results: List[ClusterResult], policy: str, slo_s: float = 1.0,
    tail_factor: float = 1.4, median_factor: float = 2.5,
    min_done: float = 0.99,
) -> int:
    """Smallest node count preserving the over-provisioned baseline's latency
    distribution (paper §5.1: consolidation must not degrade performance;
    the reference is the static-reservation cluster at max node count).
    The consolidated cluster must complete ``min_done`` of its arrivals
    within the horizon (backlog it cannot drain is an SLO breach even
    before latency is measured), hold the median, and keep the p95 within
    ``tail_factor`` of the reference tail — CFS shows 'up to 6x'
    median/tail inflation when pushed past its limit."""
    base = [r for r in results if r.policy == policy]
    n_max = max(r.n_nodes for r in base)
    ref = min((r for r in results if r.n_nodes == n_max),
              key=lambda r: r.p95)  # over-provisioned reference
    p95_budget = max(tail_factor * ref.p95, slo_s)
    p50_budget = max(median_factor * ref.p50, 0.6)
    ok = [
        r.n_nodes for r in base
        if r.p95 <= p95_budget and r.p50 <= p50_budget
        and r.done_ratio >= min_done
    ]
    return min(ok) if ok else n_max


def placement_comparison(
    total_fns: int,
    n_nodes: int,
    policy: str = "lags",
    placements: Sequence[str] = ("round-robin", "pack", "spread",
                                 "switch-aware"),
    duration_s: float = 30.0,
    slo_s: float = 1.0,
    backend: str = "numpy",
    n_cores: int = 12,
    seed: int = 7,
    exec_s: float = CLUSTER_EXEC_S,
    record_dir: Optional[str] = None,
) -> List[ClusterResult]:
    """Same (policy, n_nodes) configuration under each placement strategy —
    the per-node imbalance columns are the interesting output."""
    out = []
    for name in placements:
        asg = place(name, total_fns, n_nodes, n_cores=n_cores,
                    policy=make_policy(policy), exec_s=exec_s, seed=seed)
        fleet = simulate_fleet(
            policy, asg, duration_s=duration_s, n_cores=n_cores, seed=seed,
            exec_s=exec_s, backend=backend, distinct_seeds=True,
            record_dir=(f"{record_dir}/{name}" if record_dir else None),
        )
        out.append(cluster_result(fleet, slo_s))
    return out
