"""``repro.fleet`` — placement-aware multi-node cluster simulation.

The orchestrator layer the paper's cluster study (§5.1, Fig 7) implies but
the single-node simulators cannot express: *which functions land on which
node* (placement), *what each node then pays* (per-node tick simulation
through the ``repro.sched`` backends), and *what the fleet looks like as a
whole* (merged observability, consolidation search).

Quick start::

    from repro.fleet import place, simulate_fleet, make_policy

    asg = place("switch-aware", total_fns=800, n_nodes=10,
                policy=make_policy("lags"))
    fleet = simulate_fleet("lags", asg, duration_s=30.0)
    print(fleet.pct(95), fleet.overhead_frac, fleet.imbalance())

    # all nodes in one vmapped lax.scan (one compile per configuration):
    fleet_jax = simulate_fleet("lags", asg, backend="jax")

    # per-node run records + merged fleet view:
    simulate_fleet("lags", asg, record_dir="/tmp/fleet")
    #   python -m repro.obs.report --merge /tmp/fleet/node*

Chaos / failover (fault injection + mid-run rebalancing)::

    from repro.fleet import FaultSchedule, simulate_fleet_chaos
    sched = FaultSchedule.single_crash(node=3, t=20.0, n_nodes=10)
    res = simulate_fleet_chaos("lags", asg, sched, duration_s=60.0,
                               epoch_s=5.0)
    print(res.done_ratio, res.recovery_s(), len(res.migrations))

Topology-aware chaos (correlated rack failures, network faults,
proactive drain)::

    from repro.fleet import Topology, FaultSchedule, simulate_fleet_chaos
    topo = Topology.uniform(n_nodes=10, rack_size=5)
    sched = FaultSchedule.single_rack_crash(rack=1, t=20.0, topology=topo)
    res = simulate_fleet_chaos("lags", asg, sched, duration_s=60.0,
                               epoch_s=5.0, strategy="rack-spread",
                               proactive_drain=True)
    print(res.recovery_s(), res.reconciled, res.report())

Consolidation (the Fig 7 headline)::

    from repro.fleet import consolidation_sweep, min_nodes_meeting_slo
    res = consolidation_sweep(total_fns=800, node_counts=(14, 12, 10))
    print(min_nodes_meeting_slo(res, "cfs"), min_nodes_meeting_slo(res, "lags"))

Placement strategies (``repro.fleet.placement.PLACEMENTS``):
``round-robin`` (band-striped, the paper's banded placement), ``pack``
(first-fit decreasing by reserved share), ``spread`` (least-loaded), and
``switch-aware`` (least load *plus* the policy's voluntary-switch overhead
estimate, so dense cgroup stacking is penalised under CFS but tolerated
under run-to-completion LAGS).  Every strategy conserves the function
count — each global fn id is assigned to exactly one node.
"""
from repro.fleet.chaos import FLEET, FaultEvent, FaultSchedule, NodeState
from repro.fleet.consolidate import (
    CLUSTER_DURATION_S,
    CLUSTER_EXEC_S,
    ClusterResult,
    cluster_result,
    consolidation_sweep,
    min_nodes_meeting_slo,
    placement_comparison,
)
from repro.fleet.placement import (
    PLACEMENTS,
    Assignment,
    fn_shares,
    place,
    switch_penalty,
)
from repro.fleet.rebalance import (
    ChaosFleetResult,
    Migration,
    migration_cost_s,
    record_chaos,
    simulate_fleet_chaos,
)
from repro.fleet.simulate import FleetResult, record_fleet, simulate_fleet
from repro.fleet.topology import Topology
from repro.sched.numpy_backend import make_policy

__all__ = [
    "CLUSTER_DURATION_S", "CLUSTER_EXEC_S", "FLEET",
    "PLACEMENTS", "Assignment", "ChaosFleetResult", "ClusterResult",
    "FaultEvent", "FaultSchedule", "FleetResult", "Migration", "NodeState",
    "cluster_result", "consolidation_sweep", "fn_shares", "make_policy",
    "migration_cost_s", "min_nodes_meeting_slo", "place",
    "placement_comparison", "record_chaos", "record_fleet", "simulate_fleet",
    "simulate_fleet_chaos", "switch_penalty", "Topology",
]
