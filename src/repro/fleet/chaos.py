"""Deterministic fault injection for the fleet (chaos schedule).

A :class:`FaultSchedule` is an up-front-validated, time-ordered list of
:class:`FaultEvent`\\ s injected into a fleet run by the rebalancing
controller (:mod:`repro.fleet.rebalance`).  The grammar:

  * ``node_crash(node)``        — the node stops heartbeating and serving;
    its functions are stranded until the controller re-places them (or
    forever, under a static placement).
  * ``node_slow(node, factor)`` — the node degrades: every execution on it
    takes ``factor``x longer (thermal throttling, noisy neighbour, failing
    disk).  Detected by the :class:`~repro.distributed.fault.StragglerWatchdog`.
  * ``burst_storm(factor)``     — fleet-wide demand multiplier (a traffic
    storm): offered load scales by ``factor`` until the storm recovers.
  * ``recover(node)``           — the node (or, with ``node=-1``, the
    storm) returns to nominal.

Schedules are deterministic and replayable byte-for-byte: events are
normalised to a canonical sorted order, ``to_json``/``from_json`` round-trip
exactly, and :meth:`FaultSchedule.random` derives a schedule purely from a
seed.  Event times snap to controller epoch boundaries (the controller
applies every event with ``t < epoch_end`` at the start of that epoch), so
a schedule plus an epoch length fully determines the fleet timeline.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

#: recognised event kinds and whether they carry a factor argument
KINDS = {
    "node_crash": False,
    "node_slow": True,
    "burst_storm": True,
    "recover": False,
}

#: ``node`` value meaning "the fleet as a whole" (burst_storm / its recover)
FLEET = -1


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One timed injection.  ``node`` is ``FLEET`` (-1) for fleet-wide
    events; ``factor`` is the slowdown / rate multiplier (>= 1)."""

    t: float
    kind: str
    node: int = FLEET
    factor: float = 1.0

    def to_dict(self) -> dict:
        return {"t": self.t, "kind": self.kind, "node": self.node,
                "factor": self.factor}


class FaultSchedule:
    """Validated, time-ordered fault schedule for ``n_nodes`` fleet nodes."""

    def __init__(self, events: Iterable[FaultEvent], n_nodes: int):
        self.n_nodes = int(n_nodes)
        self.events: Tuple[FaultEvent, ...] = tuple(sorted(events))
        self._validate()

    # -- construction ------------------------------------------------------
    @classmethod
    def empty(cls, n_nodes: int) -> "FaultSchedule":
        return cls((), n_nodes)

    @classmethod
    def single_crash(cls, node: int, t: float, n_nodes: int) -> "FaultSchedule":
        """The fig_failover scenario: one node dies and stays dead."""
        return cls([FaultEvent(t, "node_crash", node)], n_nodes)

    @classmethod
    def random(cls, seed: int, n_nodes: int, duration_s: float,
               n_events: int = 4) -> "FaultSchedule":
        """Seed-deterministic schedule: crashes, slowdowns, storms and
        matched recoveries, never crashing the whole fleet."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        dead: set = set()
        slow: set = set()
        storm = False
        # draw times pre-sorted so the state tracked during generation is
        # the state in *time* order (events are time-sorted on construction)
        times = np.sort(rng.uniform(0.05, 0.95, int(n_events))) * duration_s
        for t in times:
            t = float(t)
            roll = rng.uniform()
            if roll < 0.35 and len(dead) + 1 < n_nodes:
                alive = [n for n in range(n_nodes) if n not in dead]
                node = int(rng.choice(alive))
                dead.add(node)
                slow.discard(node)
                events.append(FaultEvent(t, "node_crash", node))
            elif roll < 0.65:
                cand = [n for n in range(n_nodes) if n not in dead]
                node = int(rng.choice(cand))
                slow.add(node)
                events.append(FaultEvent(
                    t, "node_slow", node, float(rng.uniform(1.5, 4.0))))
            elif roll < 0.85 and not storm:
                storm = True
                events.append(FaultEvent(
                    t, "burst_storm", FLEET, float(rng.uniform(1.2, 2.5))))
            elif slow or storm:
                if storm and (not slow or rng.uniform() < 0.5):
                    storm = False
                    events.append(FaultEvent(t, "recover", FLEET))
                else:
                    node = int(rng.choice(sorted(slow)))
                    slow.discard(node)
                    events.append(FaultEvent(t, "recover", node))
        return cls(events, n_nodes)

    # -- validation --------------------------------------------------------
    def _validate(self) -> None:
        dead: set = set()
        slow: set = set()
        storm = False
        for ev in self.events:
            if ev.kind not in KINDS:
                raise ValueError(
                    f"unknown fault kind {ev.kind!r}; have {sorted(KINDS)}")
            if ev.t < 0.0:
                raise ValueError(f"event time must be >= 0, got {ev.t}")
            if KINDS[ev.kind] and ev.factor < 1.0:
                raise ValueError(
                    f"{ev.kind} factor must be >= 1, got {ev.factor}")
            if ev.kind == "burst_storm":
                if ev.node != FLEET:
                    raise ValueError("burst_storm is fleet-wide (node=-1)")
                storm = True
                continue
            if ev.kind == "recover" and ev.node == FLEET:
                if not storm:
                    raise ValueError(
                        f"recover(fleet) at t={ev.t} with no active storm")
                storm = False
                continue
            if not (0 <= ev.node < self.n_nodes):
                raise ValueError(
                    f"{ev.kind} node {ev.node} out of range "
                    f"[0, {self.n_nodes})")
            if ev.kind == "node_crash":
                if ev.node in dead:
                    raise ValueError(f"node {ev.node} crashed twice")
                dead.add(ev.node)
                slow.discard(ev.node)
            elif ev.kind == "node_slow":
                if ev.node in dead:
                    raise ValueError(
                        f"node_slow on already-crashed node {ev.node}")
                slow.add(ev.node)
            elif ev.kind == "recover":
                if ev.node in dead:
                    dead.discard(ev.node)
                elif ev.node in slow:
                    slow.discard(ev.node)
                else:
                    raise ValueError(
                        f"recover(node={ev.node}) at t={ev.t}: node is "
                        "neither crashed nor slow")
        if len(dead) >= self.n_nodes:
            raise ValueError("schedule crashes every node")

    # -- queries -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def events_in(self, t0: float, t1: float) -> List[FaultEvent]:
        """Events with ``t0 <= t < t1`` (the controller applies these at
        the start of the epoch covering ``[t0, t1)``)."""
        return [e for e in self.events if t0 <= e.t < t1]

    # -- replayable serialisation -----------------------------------------
    def to_json(self) -> str:
        """Canonical (sorted, fixed key order) encoding — byte-for-byte
        stable for identical schedules."""
        return json.dumps(
            {"n_nodes": self.n_nodes,
             "events": [e.to_dict() for e in self.events]},
            sort_keys=True, separators=(",", ":"),
        )

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        obj = json.loads(text)
        return cls(
            [FaultEvent(e["t"], e["kind"], e.get("node", FLEET),
                        e.get("factor", 1.0)) for e in obj["events"]],
            obj["n_nodes"],
        )


@dataclass
class NodeState:
    """The controller's view of ground-truth fleet condition: which nodes
    are up, each node's current slowdown factor, and the active demand
    multiplier.  Mutated by :meth:`apply` as events fire."""

    n_nodes: int
    alive: Optional[np.ndarray] = None
    slow: Optional[np.ndarray] = None
    storm: float = 1.0

    def __post_init__(self):
        if self.alive is None:
            self.alive = np.ones(self.n_nodes, bool)
        if self.slow is None:
            self.slow = np.ones(self.n_nodes)

    def apply(self, ev: FaultEvent) -> None:
        if ev.kind == "node_crash":
            self.alive[ev.node] = False
            self.slow[ev.node] = 1.0
        elif ev.kind == "node_slow":
            self.slow[ev.node] = ev.factor
        elif ev.kind == "burst_storm":
            self.storm = ev.factor
        elif ev.kind == "recover":
            if ev.node == FLEET:
                self.storm = 1.0
            else:
                self.alive[ev.node] = True
                self.slow[ev.node] = 1.0

    def snapshot(self) -> Dict[str, object]:
        return {
            "alive": self.alive.astype(int).tolist(),
            "slow": [round(float(x), 6) for x in self.slow],
            "storm": round(float(self.storm), 6),
        }
