"""Deterministic fault injection for the fleet (chaos schedule).

A :class:`FaultSchedule` is an up-front-validated, time-ordered list of
:class:`FaultEvent`\\ s injected into a fleet run by the rebalancing
controller (:mod:`repro.fleet.rebalance`).  The grammar:

  * ``node_crash(node)``        — the node stops heartbeating and serving;
    its functions are stranded until the controller re-places them (or
    forever, under a static placement).
  * ``node_slow(node, factor)`` — the node degrades: every execution on it
    takes ``factor``x longer (thermal throttling, noisy neighbour, failing
    disk).  Detected by the :class:`~repro.distributed.fault.StragglerWatchdog`.
  * ``burst_storm(factor)``     — fleet-wide demand multiplier (a traffic
    storm): offered load scales by ``factor`` until the storm recovers.
  * ``recover(node)``           — the node (or, with ``node=-1``, the
    storm) returns to nominal.

Topology-aware, correlated and *network* faults (these need a
:class:`repro.fleet.topology.Topology` attached to the schedule, except
the heartbeat events, which are per-node):

  * ``rack_crash(rack)``        — correlated crash: every node in the rack
    dies at once (rack power / ToR failure).  Nodes recover individually
    via ``recover(node)``.
  * ``partition(nodes, duration)`` — network partition: the listed nodes
    stop heartbeating for ``duration`` seconds but are *alive* — their
    in-flight work keeps completing.  The controller must fence them
    (SUSPECT), not declare them dead; the partition heals by itself.
  * ``heartbeat_delay(node, delay_s)`` — the node's heartbeats arrive
    ``delay_s`` late (slow control network, distinct from a slow node).
    Persistent until ``recover(node)``.
  * ``heartbeat_loss(node, p)`` — each heartbeat is dropped i.i.d. with
    probability ``p`` (lossy control network).  Persistent until
    ``recover(node)``; the drop stream is seeded by the controller.

Schedules are deterministic and replayable byte-for-byte: events are
normalised to a canonical sorted order, ``to_json``/``from_json`` round-trip
exactly (including the attached topology), and :meth:`FaultSchedule.random`
derives a schedule purely from a seed.  Event times snap to controller
epoch boundaries (the controller applies every event with ``t < epoch_end``
at the start of that epoch), so a schedule plus an epoch length fully
determines the fleet timeline.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.fleet.topology import Topology

#: recognised event kinds and whether they carry a factor argument
#: (for the network events the "factor" is the delay in seconds /
#: the drop probability — validated per kind, see ``_validate``)
KINDS = {
    "node_crash": False,
    "node_slow": True,
    "burst_storm": True,
    "recover": False,
    "rack_crash": False,
    "partition": False,
    "heartbeat_delay": True,
    "heartbeat_loss": True,
}

#: ``node`` value meaning "the fleet as a whole" (burst_storm / its recover)
FLEET = -1


@dataclass(frozen=True, order=True)
class FaultEvent:
    """One timed injection.  ``node`` is ``FLEET`` (-1) for fleet-wide
    events; ``factor`` is the slowdown / rate multiplier (>= 1) — for
    ``heartbeat_delay`` it is the delay in seconds (> 0), for
    ``heartbeat_loss`` the drop probability (0 < p <= 1).  ``rack``
    addresses ``rack_crash``; ``nodes``/``duration`` describe a
    ``partition`` window ``[t, t + duration)``."""

    t: float
    kind: str
    node: int = FLEET
    factor: float = 1.0
    rack: int = -1
    nodes: Tuple[int, ...] = ()
    duration: float = 0.0

    def to_dict(self) -> dict:
        d = {"t": self.t, "kind": self.kind, "node": self.node,
             "factor": self.factor}
        # optional fields stay out of the encoding at their defaults, so
        # pre-topology schedules keep their exact historical bytes
        if self.rack >= 0:
            d["rack"] = self.rack
        if self.nodes:
            d["nodes"] = list(self.nodes)
        if self.duration:
            d["duration"] = self.duration
        return d


class FaultSchedule:
    """Validated, time-ordered fault schedule for ``n_nodes`` fleet nodes.

    ``topology`` (optional) attaches the failure-domain map; rack-scoped
    events (``rack_crash``) require it and are validated against it.
    """

    def __init__(self, events: Iterable[FaultEvent], n_nodes: int,
                 topology: Optional[Topology] = None):
        self.n_nodes = int(n_nodes)
        self.topology = topology
        if topology is not None and topology.n_nodes != self.n_nodes:
            raise ValueError(
                f"topology covers {topology.n_nodes} nodes, schedule is "
                f"for {self.n_nodes}")
        self.events: Tuple[FaultEvent, ...] = tuple(sorted(events))
        self._validate()

    # -- construction ------------------------------------------------------
    @classmethod
    def empty(cls, n_nodes: int,
              topology: Optional[Topology] = None) -> "FaultSchedule":
        return cls((), n_nodes, topology)

    @classmethod
    def single_crash(cls, node: int, t: float, n_nodes: int) -> "FaultSchedule":
        """The fig_failover scenario: one node dies and stays dead."""
        return cls([FaultEvent(t, "node_crash", node)], n_nodes)

    @classmethod
    def single_rack_crash(cls, rack: int, t: float,
                          topology: Topology) -> "FaultSchedule":
        """Correlated failure: every node in ``rack`` dies at ``t``."""
        return cls([FaultEvent(t, "rack_crash", rack=rack)],
                   topology.n_nodes, topology)

    @classmethod
    def single_partition(cls, nodes: Iterable[int], t: float,
                         duration: float, n_nodes: int,
                         topology: Optional[Topology] = None,
                         ) -> "FaultSchedule":
        """Pure network fault: ``nodes`` stop heartbeating for
        ``duration`` seconds but keep serving their in-flight work."""
        return cls(
            [FaultEvent(t, "partition", nodes=tuple(int(n) for n in nodes),
                        duration=float(duration))],
            n_nodes, topology)

    @classmethod
    def random(cls, seed: int, n_nodes: int, duration_s: float,
               n_events: int = 4,
               topology: Optional[Topology] = None) -> "FaultSchedule":
        """Seed-deterministic schedule: crashes, slowdowns, storms and
        matched recoveries, never crashing the whole fleet.  With a
        ``topology`` the draw also includes the correlated and network
        events (rack crashes, partitions, heartbeat delay/loss); without
        one the byte sequence is identical to the pre-topology grammar.
        """
        if topology is not None and topology.n_nodes != int(n_nodes):
            raise ValueError(
                f"topology covers {topology.n_nodes} nodes, asked for "
                f"{n_nodes}")
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        dead: set = set()
        slow: set = set()
        netty: set = set()  # nodes with an active heartbeat delay/loss
        part_until: Dict[int, float] = {}  # node -> partition window end
        storm = False
        # draw times pre-sorted so the state tracked during generation is
        # the state in *time* order (events are time-sorted on construction)
        times = np.sort(rng.uniform(0.05, 0.95, int(n_events))) * duration_s
        for t in times:
            t = float(t)
            roll = rng.uniform()
            alive = [n for n in range(n_nodes) if n not in dead]
            if topology is not None:
                # topology-aware extension: a slice of the roll space goes
                # to correlated / network faults, the rest falls through to
                # the legacy grammar (rescaled)
                if roll < 0.12:
                    live_racks = [
                        r for r in range(topology.n_racks)
                        if not any(n in dead for n in topology.nodes_in(r))
                        and len(dead) + len(topology.nodes_in(r)) < n_nodes
                    ]
                    if live_racks:
                        rack = int(rng.choice(live_racks))
                        for n in topology.nodes_in(rack):
                            dead.add(n)
                            slow.discard(n)
                            netty.discard(n)
                        events.append(FaultEvent(t, "rack_crash", rack=rack))
                        continue
                elif roll < 0.24:
                    cand = [n for n in alive
                            if part_until.get(n, -1.0) <= t]
                    if cand:
                        k = int(rng.integers(1, min(len(cand), 3) + 1))
                        ns = tuple(sorted(
                            int(x) for x in rng.choice(cand, k, replace=False)
                        ))
                        dur = float(rng.uniform(0.05, 0.25) * duration_s)
                        for n in ns:
                            part_until[n] = t + dur
                        events.append(FaultEvent(
                            t, "partition", nodes=ns, duration=dur))
                        continue
                elif roll < 0.36:
                    cand = [n for n in alive if n not in netty]
                    if cand:
                        node = int(rng.choice(cand))
                        netty.add(node)
                        if rng.uniform() < 0.5:
                            events.append(FaultEvent(
                                t, "heartbeat_delay", node,
                                float(rng.uniform(0.02, 0.3) * duration_s)))
                        else:
                            events.append(FaultEvent(
                                t, "heartbeat_loss", node,
                                float(rng.uniform(0.3, 1.0))))
                        continue
                roll = rng.uniform()  # fresh roll for the legacy grammar
            if roll < 0.35 and len(dead) + 1 < n_nodes:
                node = int(rng.choice(alive))
                dead.add(node)
                slow.discard(node)
                netty.discard(node)
                events.append(FaultEvent(t, "node_crash", node))
            elif roll < 0.65:
                cand = [n for n in range(n_nodes) if n not in dead]
                node = int(rng.choice(cand))
                slow.add(node)
                events.append(FaultEvent(
                    t, "node_slow", node, float(rng.uniform(1.5, 4.0))))
            elif roll < 0.85 and not storm:
                storm = True
                events.append(FaultEvent(
                    t, "burst_storm", FLEET, float(rng.uniform(1.2, 2.5))))
            elif slow or netty or storm:
                if storm and (not (slow or netty) or rng.uniform() < 0.5):
                    storm = False
                    events.append(FaultEvent(t, "recover", FLEET))
                else:
                    node = int(rng.choice(sorted(slow | netty)))
                    slow.discard(node)
                    netty.discard(node)
                    events.append(FaultEvent(t, "recover", node))
        return cls(events, n_nodes, topology)

    # -- validation --------------------------------------------------------
    def _validate(self) -> None:
        dead: set = set()
        slow: set = set()
        netty: set = set()  # active heartbeat delay/loss
        parts: List[Tuple[float, float, frozenset]] = []  # (t0, t1, nodes)
        storm = False
        for ev in self.events:
            if ev.kind not in KINDS:
                raise ValueError(
                    f"unknown fault kind {ev.kind!r}; have {sorted(KINDS)}")
            if ev.t < 0.0:
                raise ValueError(f"event time must be >= 0, got {ev.t}")
            if ev.kind in ("node_slow", "burst_storm") and ev.factor < 1.0:
                raise ValueError(
                    f"{ev.kind} factor must be >= 1, got {ev.factor}")
            if ev.kind == "burst_storm":
                if ev.node != FLEET:
                    raise ValueError("burst_storm is fleet-wide (node=-1)")
                storm = True
                continue
            if ev.kind == "recover" and ev.node == FLEET:
                if not storm:
                    raise ValueError(
                        f"recover(fleet) at t={ev.t} with no active storm")
                storm = False
                continue
            if ev.kind == "rack_crash":
                if self.topology is None:
                    raise ValueError(
                        "rack_crash needs a topology attached to the "
                        "schedule")
                if not (0 <= ev.rack < self.topology.n_racks):
                    raise ValueError(
                        f"rack_crash rack {ev.rack} out of range "
                        f"[0, {self.topology.n_racks})")
                members = self.topology.nodes_in(ev.rack)
                hit = [n for n in members if n in dead]
                if hit:
                    raise ValueError(
                        f"rack_crash(rack={ev.rack}) at t={ev.t} overlaps "
                        f"already-crashed node(s) {hit}")
                for n in members:
                    dead.add(n)
                    slow.discard(n)
                    netty.discard(n)
                continue
            if ev.kind == "partition":
                if not ev.nodes:
                    raise ValueError("partition needs a non-empty node set")
                if len(set(ev.nodes)) != len(ev.nodes):
                    raise ValueError(
                        f"partition node set has duplicates: {ev.nodes}")
                bad = [n for n in ev.nodes
                       if not (0 <= n < self.n_nodes)]
                if bad:
                    raise ValueError(
                        f"partition node(s) {bad} out of range "
                        f"[0, {self.n_nodes})")
                if ev.duration <= 0.0:
                    raise ValueError(
                        f"partition duration must be > 0, got {ev.duration}")
                crashed = [n for n in ev.nodes if n in dead]
                if crashed:
                    raise ValueError(
                        f"partition of already-crashed node(s) {crashed} "
                        f"at t={ev.t}")
                ns = frozenset(ev.nodes)
                for (p0, p1, pn) in parts:
                    if ev.t < p1 and ns & pn:
                        raise ValueError(
                            f"overlapping partitions of node(s) "
                            f"{sorted(ns & pn)}: [{p0}, {p1}) and "
                            f"[{ev.t}, {ev.t + ev.duration})")
                parts.append((ev.t, ev.t + ev.duration, ns))
                continue
            if not (0 <= ev.node < self.n_nodes):
                raise ValueError(
                    f"{ev.kind} node {ev.node} out of range "
                    f"[0, {self.n_nodes})")
            if ev.kind == "node_crash":
                if ev.node in dead:
                    raise ValueError(f"node {ev.node} crashed twice")
                dead.add(ev.node)
                slow.discard(ev.node)
                netty.discard(ev.node)
            elif ev.kind == "node_slow":
                if ev.node in dead:
                    raise ValueError(
                        f"node_slow on already-crashed node {ev.node}")
                slow.add(ev.node)
            elif ev.kind == "heartbeat_delay":
                if ev.factor <= 0.0:
                    raise ValueError(
                        f"heartbeat_delay must be > 0 s, got {ev.factor}")
                if ev.node in dead:
                    raise ValueError(
                        f"heartbeat_delay on already-crashed node "
                        f"{ev.node} (a dead node sends no heartbeats)")
                netty.add(ev.node)
            elif ev.kind == "heartbeat_loss":
                if not (0.0 < ev.factor <= 1.0):
                    raise ValueError(
                        f"heartbeat_loss probability must be in (0, 1], "
                        f"got {ev.factor}")
                if ev.node in dead:
                    raise ValueError(
                        f"heartbeat_loss on already-crashed node {ev.node}")
                netty.add(ev.node)
            elif ev.kind == "recover":
                if ev.node in dead:
                    dead.discard(ev.node)
                    netty.discard(ev.node)
                elif ev.node in slow or ev.node in netty:
                    slow.discard(ev.node)
                    netty.discard(ev.node)
                else:
                    raise ValueError(
                        f"recover(node={ev.node}) at t={ev.t}: node is "
                        "neither crashed nor slow nor degraded on the "
                        "heartbeat network")
        if len(dead) >= self.n_nodes:
            raise ValueError("schedule crashes every node")

    # -- queries -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.events)

    def __bool__(self) -> bool:
        return bool(self.events)

    def events_in(self, t0: float, t1: float) -> List[FaultEvent]:
        """Events with ``t0 <= t < t1`` (the controller applies these at
        the start of the epoch covering ``[t0, t1)``)."""
        return [e for e in self.events if t0 <= e.t < t1]

    # -- replayable serialisation -----------------------------------------
    def to_json(self) -> str:
        """Canonical (sorted, fixed key order) encoding — byte-for-byte
        stable for identical schedules.  Pre-topology schedules keep
        their historical bytes (no new keys at default values)."""
        obj = {"n_nodes": self.n_nodes,
               "events": [e.to_dict() for e in self.events]}
        if self.topology is not None:
            obj["topology"] = self.topology.to_obj()
        return json.dumps(obj, sort_keys=True, separators=(",", ":"))

    @classmethod
    def from_json(cls, text: str) -> "FaultSchedule":
        obj = json.loads(text)
        topo = (Topology.from_obj(obj["topology"])
                if "topology" in obj else None)
        return cls(
            [FaultEvent(e["t"], e["kind"], e.get("node", FLEET),
                        e.get("factor", 1.0), e.get("rack", -1),
                        tuple(e.get("nodes", ())), e.get("duration", 0.0))
             for e in obj["events"]],
            obj["n_nodes"], topo,
        )


@dataclass
class NodeState:
    """Ground-truth fleet condition: which nodes are up, each node's
    current slowdown factor, the active demand multiplier, and the
    *network* condition per node (partition window, heartbeat delay,
    heartbeat drop probability).  Mutated by :meth:`apply` as events
    fire.  Note the controller never reads this directly — its view of
    liveness comes from the heartbeat/progress evidence the network
    faults distort."""

    n_nodes: int
    alive: Optional[np.ndarray] = None
    slow: Optional[np.ndarray] = None
    storm: float = 1.0
    part_until: Optional[np.ndarray] = None  # partition active while t <
    hb_delay: Optional[np.ndarray] = None  # seconds each heartbeat is late
    hb_loss: Optional[np.ndarray] = None  # P(drop) per heartbeat

    def __post_init__(self):
        if self.alive is None:
            self.alive = np.ones(self.n_nodes, bool)
        if self.slow is None:
            self.slow = np.ones(self.n_nodes)
        if self.part_until is None:
            self.part_until = np.zeros(self.n_nodes)
        if self.hb_delay is None:
            self.hb_delay = np.zeros(self.n_nodes)
        if self.hb_loss is None:
            self.hb_loss = np.zeros(self.n_nodes)

    def apply(self, ev: FaultEvent,
              topology: Optional[Topology] = None) -> None:
        if ev.kind == "node_crash":
            self._crash(ev.node)
        elif ev.kind == "rack_crash":
            if topology is None:
                raise ValueError("rack_crash needs a topology to expand")
            for n in topology.nodes_in(ev.rack):
                self._crash(n)
        elif ev.kind == "partition":
            for n in ev.nodes:
                self.part_until[n] = max(
                    float(self.part_until[n]), ev.t + ev.duration)
        elif ev.kind == "heartbeat_delay":
            self.hb_delay[ev.node] = ev.factor
        elif ev.kind == "heartbeat_loss":
            self.hb_loss[ev.node] = ev.factor
        elif ev.kind == "node_slow":
            self.slow[ev.node] = ev.factor
        elif ev.kind == "burst_storm":
            self.storm = ev.factor
        elif ev.kind == "recover":
            if ev.node == FLEET:
                self.storm = 1.0
            else:
                self.alive[ev.node] = True
                self.slow[ev.node] = 1.0
                self.hb_delay[ev.node] = 0.0
                self.hb_loss[ev.node] = 0.0

    def _crash(self, node: int) -> None:
        self.alive[node] = False
        self.slow[node] = 1.0
        # a dead node sends no heartbeats at all; its link state is moot
        self.hb_delay[node] = 0.0
        self.hb_loss[node] = 0.0

    def partitioned(self, t: float) -> np.ndarray:
        """Mask of nodes inside an active partition window at time ``t``."""
        return (self.part_until > t) & self.alive

    def snapshot(self) -> Dict[str, object]:
        snap = {
            "alive": self.alive.astype(int).tolist(),
            "slow": [round(float(x), 6) for x in self.slow],
            "storm": round(float(self.storm), 6),
        }
        if (self.part_until > 0).any():
            snap["part_until"] = [round(float(x), 6)
                                  for x in self.part_until]
        if (self.hb_delay > 0).any():
            snap["hb_delay"] = [round(float(x), 6) for x in self.hb_delay]
        if (self.hb_loss > 0).any():
            snap["hb_loss"] = [round(float(x), 6) for x in self.hb_loss]
        return snap
