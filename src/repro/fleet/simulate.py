"""Multi-node fleet simulation over the ``repro.sched`` backends.

Runs one tick simulation per fleet node under a placement
(:class:`repro.fleet.placement.Assignment`) and aggregates the results:

  * ``backend="numpy"`` — the exact per-node loop through
    ``core.simkernel.simulate`` (float64 reference).  Nodes whose
    (function count, seed) coincide share one simulation — under the
    default shared seed, equal-count nodes are *statistically identical*
    (the paper's banded-placement assumption), so a balanced fleet costs
    one node-sim, not ``n_nodes``.
  * ``backend="jax"`` — all nodes of a configuration batched into **one**
    ``vmap``-ped ``lax.scan`` over ``core.simkernel_jax``: per-node slot
    traces are padded to a common shape and stacked, so a 14-node sweep
    costs a single compile and runs data-parallel on the accelerator.

Per-node demand is regenerated from the band model at the node's assigned
function count (``traces.make_workload``), which keeps the differential
contract with the legacy representative-node path: a placement handing
every node ``k`` functions reproduces ``simulate_node_share(policy, k*n,
n)`` exactly (``tests/test_fleet.py``).  Pass ``distinct_seeds=True`` to
decorrelate nodes instead.

Fleet observability: ``record_dir`` makes every simulated node emit a run
record (``node<i>/run.json``); render the merged fleet view with

  python -m repro.obs.report --merge RECORD_DIR/node*
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.simkernel import SimConfig, SimResult, simulate
from repro.core.traces import make_workload
from repro.fleet.placement import Assignment
from repro.obs.schedstats import SchedStats
from repro.sched.numpy_backend import make_policy


@dataclass
class FleetResult:
    """Aggregated fleet run: one :class:`SimResult` per node."""

    policy: str
    placement: str
    nodes: List[SimResult]
    counts: np.ndarray  # per-node function counts
    duration_s: float
    n_cores: int
    backend: str = "numpy"

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def latencies(self) -> np.ndarray:
        xs = [r.latencies for r in self.nodes if len(r.latencies)]
        return np.concatenate(xs) if xs else np.empty(0)

    @property
    def n_arrived(self) -> int:
        return sum(r.n_arrived for r in self.nodes)

    @property
    def n_completed(self) -> int:
        return sum(r.n_completed for r in self.nodes)

    def pct(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if len(lat) else float("nan")

    def throughput_slo(self, slo: float = 1.0) -> float:
        return float(np.sum(self.latencies <= slo)) / self.duration_s

    @property
    def util_effective(self) -> float:
        cap = self.n_nodes * self.n_cores * self.duration_s
        return sum(r.busy_time_s for r in self.nodes) / cap

    @property
    def util_perceived(self) -> float:
        cap = self.n_nodes * self.n_cores * self.duration_s
        return sum(r.busy_time_s + r.switch_time_s for r in self.nodes) / cap

    @property
    def overhead_frac(self) -> float:
        cap = self.n_nodes * self.n_cores * self.duration_s
        return sum(r.switch_time_s for r in self.nodes) / cap

    # -- fleet observability ------------------------------------------------
    def node_p95s(self) -> np.ndarray:
        return np.asarray([r.pct(95) for r in self.nodes])

    def imbalance(self) -> dict:
        """Per-node load-imbalance report: p95 spread across nodes and the
        max/mean overhead-fraction ratio (1.0 = perfectly balanced)."""
        p95 = self.node_p95s()
        ovh = np.asarray([r.overhead_frac for r in self.nodes])
        ok = p95 == p95  # drop NaN (empty nodes)
        return {
            "p95_min": float(p95[ok].min()) if ok.any() else float("nan"),
            "p95_max": float(p95[ok].max()) if ok.any() else float("nan"),
            "p95_spread": (
                float(p95[ok].max() - p95[ok].min()) if ok.any()
                else float("nan")
            ),
            "ovh_max_over_mean": float(
                ovh.max() / max(ovh.mean(), 1e-12)
            ),
        }

    def merged_sched(self) -> SchedStats:
        """One fleet-wide :class:`SchedStats` (entity stats summed)."""
        out = SchedStats(f"fleet.{self.policy}.{self.placement}")
        for r in self.nodes:
            out.merge(r.sched_summary())
        return out


def _empty_node(policy_name: str, duration_s: float, n_cores: int,
                backend: str) -> SimResult:
    """A node the placement left idle (``pack`` drains the tail nodes)."""
    return SimResult(
        policy=policy_name, latencies=np.empty(0),
        fn_of=np.empty(0, np.int64), arrival_of=np.empty(0),
        n_arrived=0, n_completed=0, switches=0, switch_time_s=0.0,
        busy_time_s=0.0, duration_s=duration_s, n_cores=n_cores,
    )


def _node_sim_numpy(policy_name: str, n_fns: int, duration_s: float,
                    n_cores: int, seed: int, exec_s: float,
                    threads_per_fn: int,
                    rates: Optional[np.ndarray] = None,
                    fn_ids: Optional[np.ndarray] = None,
                    extra: Optional[np.ndarray] = None) -> SimResult:
    wl = make_workload(
        "azure2021", n_fns, duration_s=duration_s, n_cores=n_cores,
        seed=seed, exec_s=exec_s,
        threads_per_fn=threads_per_fn, rates=rates, fn_ids=fn_ids,
        extra=extra,
    )
    return simulate(
        wl, make_policy(policy_name),
        SimConfig(n_cores=n_cores, hierarchy_depth=5.0, burst_us=280.0,
                  seed=seed),
    )


def _pad_trace(trace, T: int, R: int):
    """Pad a SlotTrace to (T, R) with never-arriving requests.

    Padding slots carry the sentinel arrival (never runnable) and fn id 0
    (never dispatched, so the mapping is inert); the scan result over a
    padded trace is bit-identical to the unpadded one.
    """
    import jax.numpy as jnp

    BIG = np.iinfo(np.int32).max // 2
    at = np.full((T, R), BIG, np.int32)
    de = np.zeros((T, R), np.float32)
    fn = np.zeros(T, np.int32)
    t0, r0 = trace.arrival_tick.shape
    at[:t0, :r0] = np.asarray(trace.arrival_tick)
    de[:t0, :r0] = np.asarray(trace.demand)
    fn[:t0] = np.asarray(trace.slot_fn)
    return type(trace)(jnp.asarray(at), jnp.asarray(de), jnp.asarray(fn))


def _fleet_sim_jax(policy_name: str, counts: np.ndarray, duration_s: float,
                   n_cores: int, seeds: List[int], exec_s,
                   threads_per_fn: int,
                   rates: Optional[List[Optional[np.ndarray]]] = None,
                   fn_ids: Optional[List[Optional[np.ndarray]]] = None,
                   extra: Optional[List[Optional[np.ndarray]]] = None,
                   ) -> List[SimResult]:
    """All nodes of one configuration in a single vmapped ``lax.scan``.

    ``exec_s`` is a scalar or one per-node execution time (chaos slowdowns);
    ``rates`` optionally carries explicit per-node request-rate vectors,
    ``fn_ids`` the matching global function ids (common random numbers)
    and ``extra`` per-node exact-count replay arrivals.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import simkernel_jax as sj
    from repro.sched.jax_backend import CODE_OF

    execs = ([float(exec_s)] * len(counts) if np.isscalar(exec_s)
             else [float(e) for e in exec_s])
    node_rates = rates if rates is not None else [None] * len(counts)
    node_fids = fn_ids if fn_ids is not None else [None] * len(counts)
    node_extra = extra if extra is not None else [None] * len(counts)
    traces = []
    for k, seed, ex, r, fids, xt in zip(counts, seeds, execs, node_rates,
                                        node_fids, node_extra):
        wl = make_workload(
            "azure2021", int(k), duration_s=duration_s, n_cores=n_cores,
            seed=seed, exec_s=ex, threads_per_fn=threads_per_fn, rates=r,
            fn_ids=fids, extra=xt,
        )
        traces.append(sj.build_slot_trace(wl, int(k), threads_per_fn))
    max_fns = int(max(counts))
    T = max_fns * threads_per_fn
    R = max(int(t.arrival_tick.shape[1]) for t in traces)
    padded = [_pad_trace(t, T, R) for t in traces]
    stacked = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *padded
    )
    p = sj.SimParams(
        n_cores=n_cores, n_fns=max_fns,
        n_ticks=int(duration_s / sj.TICK), policy=CODE_OF[policy_name],
        burst_us=280.0, depth=5.0,
    )
    out = jax.vmap(lambda t: sj.simulate(t, p))(stacked)

    results = []
    BIG = np.iinfo(np.int32).max // 2
    for i, trace in enumerate(padded):
        done = np.asarray(out["done_tick"][i])
        lat = sj.latencies_from(trace, done)
        at = np.asarray(trace.arrival_tick)
        ok = (done >= 0) & (at < BIG)
        fn_of = np.broadcast_to(
            np.asarray(trace.slot_fn)[:, None], at.shape
        )[ok]
        results.append(SimResult(
            policy=policy_name,
            latencies=lat,
            fn_of=fn_of,
            arrival_of=at[ok] * sj.TICK,
            n_arrived=int((at < BIG).sum()),
            n_completed=len(lat),
            switches=0,
            switch_time_s=float(out["overhead_s"][i]),
            busy_time_s=float(out["busy_s"][i]),
            duration_s=duration_s,
            n_cores=n_cores,
        ))
    return results


def simulate_fleet(
    policy_name: str,
    assignment: Assignment,
    duration_s: float = 30.0,
    n_cores: int = 12,
    seed: int = 7,
    exec_s: float = 0.2,
    backend: str = "numpy",
    distinct_seeds: bool = False,
    threads_per_fn: int = 0,
    record_dir: Optional[str] = None,
    node_exec_mult: Optional[np.ndarray] = None,
    dead: Optional[np.ndarray] = None,
    node_rates: Optional[List[Optional[np.ndarray]]] = None,
    node_extra: Optional[List[Optional[np.ndarray]]] = None,
) -> FleetResult:
    """Simulate every node of a placed fleet; see the module docstring.

    Chaos hooks (used by :mod:`repro.fleet.rebalance`): ``node_exec_mult``
    scales each node's per-request execution time (a degraded/slow node
    serves the same demand more slowly), ``dead`` marks crashed nodes —
    they are not simulated and appear as explicit zero-work nodes (their
    stranded arrivals are accounted by the chaos controller, not here) —
    and ``node_rates`` gives each node explicit per-function request
    rates, so a node's offered load follows the functions *assigned* to
    it (after a migration the regenerate-by-count band model would lose
    the moved functions' demand mass).  Rate-based nodes draw each
    function's arrival stream from ``(seed, global fn id)`` — common
    random numbers, so a function keeps its realization across
    placements — and bypass the equal-count cache (their workloads are
    no longer statistically identical).  ``node_extra`` (requires
    ``node_rates``) adds exact-count replay arrivals per function — the
    chaos layer's retry-backlog and epoch-carryover channel.
    """
    counts = assignment.counts
    assert int(counts.sum()) == int(assignment.shares.shape[0]), (
        "placement dropped functions"  # Assignment already guards this
    )
    seeds = [seed + i if distinct_seeds else seed
             for i in range(assignment.n_nodes)]
    mult = (np.ones(assignment.n_nodes) if node_exec_mult is None
            else np.asarray(node_exec_mult, float))
    is_dead = (np.zeros(assignment.n_nodes, bool) if dead is None
               else np.asarray(dead, bool))
    rate_of = (node_rates if node_rates is not None
               else [None] * assignment.n_nodes)
    extra_of = (node_extra if node_extra is not None
                else [None] * assignment.n_nodes)
    live = [(i, int(k)) for i, k in enumerate(counts)
            if k > 0 and not is_dead[i]]
    fids_of = [
        np.asarray(assignment.node_fns[i], np.int64)
        if rate_of[i] is not None else None
        for i in range(assignment.n_nodes)
    ]
    if backend == "jax":
        tpf = threads_per_fn or 8
        sims = _fleet_sim_jax(
            policy_name, np.asarray([k for _, k in live]), duration_s,
            n_cores, [seeds[i] for i, _ in live],
            [exec_s * float(mult[i]) for i, _ in live], tpf,
            rates=[rate_of[i] for i, _ in live],
            fn_ids=[fids_of[i] for i, _ in live],
            extra=[extra_of[i] for i, _ in live],
        )
        by_node = {i: r for (i, _), r in zip(live, sims)}
    elif backend == "numpy":
        tpf = threads_per_fn or 192
        cache: Dict[Tuple, SimResult] = {}
        by_node = {}
        for i, k in live:
            r = rate_of[i]
            key = (k, int(seeds[i]), float(mult[i]),
                   None if r is None else hash(np.asarray(r).tobytes()),
                   None if fids_of[i] is None
                   else hash(fids_of[i].tobytes()),
                   None if extra_of[i] is None
                   else hash(np.asarray(extra_of[i], np.int64).tobytes()))
            if key not in cache:
                cache[key] = _node_sim_numpy(
                    policy_name, k, duration_s, n_cores, int(seeds[i]),
                    exec_s * float(mult[i]), tpf, rates=r,
                    fn_ids=fids_of[i], extra=extra_of[i],
                )
            by_node[i] = cache[key]
    else:
        raise ValueError(f"unknown backend {backend!r}")
    nodes = [
        by_node.get(i) or _empty_node(policy_name, duration_s, n_cores,
                                      backend)
        for i in range(assignment.n_nodes)
    ]

    fleet = FleetResult(
        policy=policy_name,
        placement=assignment.placement,
        nodes=nodes,
        counts=counts,
        duration_s=duration_s,
        n_cores=n_cores,
        backend=backend,
    )
    if record_dir:
        record_fleet(fleet, record_dir)
    return fleet


def record_fleet(fleet: FleetResult, out_dir: str) -> List[str]:
    """Emit one run record per simulated node (``node<i>/run.json``).

    Uses each node's ``sched_summary()`` so records exist telemetry-on or
    -off; merge them back into one fleet view with
    ``python -m repro.obs.report --merge out_dir/node*``.
    """
    from repro.obs.recorder import record_run

    paths = []
    for i, r in enumerate(fleet.nodes):
        paths.append(record_run(
            os.path.join(out_dir, f"node{i}"),
            meta={
                "layer": "fleet", "policy": fleet.policy,
                "placement": fleet.placement, "node": i,
                "n_nodes": fleet.n_nodes, "n_fns": int(fleet.counts[i]),
                "duration_s": fleet.duration_s, "backend": fleet.backend,
            },
            sched=r.sched_summary(),
            include_registry=False,
        ))
    return paths
