"""Epoch-driven failover controller: detect, re-place, charge, recover.

The missing operational half of the consolidation headline (Fig 7): a
densely packed 10-node LAGS fleet carries 40 % more functions per node
than the 14-node CFS baseline, so a crashed or degraded node strands more
work — recovery behaviour is part of the claim.  This module splits a
fleet run into controller epochs and closes the loop each epoch:

  1. **inject** — apply the :class:`~repro.fleet.chaos.FaultSchedule`
     events that fall in the epoch (crash / slow / storm / recover);
  2. **observe** — simulate the epoch over the live nodes
     (:func:`repro.fleet.simulate.simulate_fleet` with per-node slowdown
     multipliers and a dead mask) and feed the per-epoch schedstats into
     the detection stack: heartbeats into
     :class:`repro.distributed.fault.HealthTracker`, per-request service
     time into :class:`repro.distributed.fault.StragglerWatchdog`;
  3. **re-place** — migrate the detected victims' functions onto the
     survivors through the existing placement registry (``spread`` /
     ``switch-aware`` / ... warm-started with the survivors' current
     load), producing a new conservation-checked
     :class:`~repro.fleet.placement.Assignment` — every live function on
     exactly one live node, every epoch;
  4. **charge** — failover is never free: each migrated function pays a
     migration cost priced through the policy's own
     ``Policy.voluntary_switch`` cost model at the *destination* density
     (C-Balancer-style migration, priced à la constraint-based repacking
     — see PAPERS.md), folded into the merged schedstats as switch
     overhead.

Functions assigned to a dead node are *stranded*: their would-be arrivals
accumulate in a retry backlog (clients re-issue failed invocations).  The
first epoch in which a stranded function is live again — re-placed onto a
survivor, or its node recovered — replays its backlog on top of the
nominal offered load, injected as **exact-count** arrivals spread over
the epoch (``make_workload(extra=...)``): a backlog is known pending
requests, and routing it through the bursty MMPP rate process instead
would replay a random multiple of its mass.  Under ``rebalance=False`` (the static-placement
baseline ``benchmarks/fig_failover.py`` compares against) a crashed
node's backlog is never drained and is reported as ``lost_arrivals``.

Epoch boundaries are **work-conserving** (``carry_unfinished``): arrivals
a live node admitted but did not complete inside its epoch are re-offered
in the next epoch, to whichever node their function then lives on.  The
un-epoched simulator drains its queues over the whole horizon; censoring
queued work at every boundary instead would systematically penalise
exactly the runs that queue more — the post-failover survivors carrying a
dead node's functions — and bias any recovery comparison against them.
Progress is conserved alongside the arrivals: the partial service a
node performed on still-in-flight requests (busy seconds beyond the cost
of its completed requests, in request-equivalents) is credited against
the carried counts, so boundary-spanning requests complete from
conserved progress instead of restarting from zero.  Without the credit
every boundary levies a restart tax proportional to in-flight inventory
— positive feedback that drives precisely the loaded survivors into
runaway backlog the continuous simulator would never show.

A run with an **empty schedule and no epoch override is bit-identical to
:func:`simulate_fleet`** (it delegates — the differential test in
``tests/test_chaos.py`` pins this), so the chaos layer costs nothing when
unused.

**Topology-aware failure handling.**  With a
:class:`~repro.fleet.topology.Topology` the schedule can crash whole
racks and inject *network* faults — partitions, delayed and lossy
heartbeats — that today's crash detector would misread as node death.
The controller therefore models the heartbeat network explicitly (an
in-flight queue with per-node delay and seeded loss; a delivered
heartbeat is evidence of the node at its *send* time, not its arrival
time) and feeds a second evidence channel,
``HealthTracker.observe_progress``, from completions it can see in the
epoch results.  Detection becomes a ladder: a node whose heartbeats are
overdue but whose work keeps landing is **SUSPECT** and gets *fenced* —
its nominal arrivals are deferred into the retry backlog (replayed on
heal: reconciliation), it serves only its in-flight carryover, and it is
excluded as a migration destination — while only heartbeat-silent,
progress-stale nodes are CONFIRMED-DEAD and failed over.  Fencing never
re-places, so the conservation invariant (every fn on exactly one node)
holds even when the controller's liveness view is wrong.  With
``proactive_drain=True`` a :class:`~repro.distributed.fault.TrendDetector`
watches each node's per-request service time against the healthy-fleet
mean and migrates load off nodes *trending* degraded before the
watchdog would quarantine them — hysteresis (enter/exit ratio band +
persistence) guarantees the drain decision never flaps.  When a victim
is failed over under a topology, destinations avoid the failing rack(s)
when any other rack has capacity, and the ``rack-spread`` strategy keeps
the re-placed share balanced across the surviving domains.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.switch_cost import switch_cost_us
from repro.core.traces import make_workload
from repro.distributed.fault import (
    HealthTracker,
    StragglerWatchdog,
    TrendDetector,
)
from repro.fleet.chaos import FLEET, FaultSchedule, NodeState
from repro.fleet.placement import (
    PLACEMENTS,
    Assignment,
    _DensityProbe,
)
from repro.fleet.simulate import FleetResult, simulate_fleet
from repro.fleet.topology import Topology
from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.obs.schedstats import SchedStats
from repro.sched.numpy_backend import Policy, make_policy

#: one migration ~ this many cross-cgroup handoffs at the destination
#: density (cgroup freeze + state transfer + cache warmup dwarf a single
#: context switch)
MIGRATION_COLD_MULT = 400.0

_EPOCH_SEED_STRIDE = 104729  # decorrelates per-epoch band workloads

#: heartbeat sends per control epoch — cadence finer than the control
#: interval so a sub-epoch delivery delay shifts staleness by the actual
#: delay rather than quantizing it up to a full epoch (which would trip
#: the suspect timeout for arbitrarily small delays)
HB_PER_EPOCH = 3


def migration_cost_s(
    policy: Policy,
    n_groups_dest: int,
    n_cores: int = 12,
    depth: float = 5.0,
    cold_mult: float = MIGRATION_COLD_MULT,
) -> float:
    """Seconds charged for migrating one function cgroup onto a node that
    will host ``n_groups_dest`` colocated cgroups.

    Priced through the same ``Policy.voluntary_switch`` model placement
    uses (:func:`repro.fleet.placement.switch_penalty`): CFS pays its
    log-growing cross-cgroup cost at the destination density, LAGS's
    run-to-completion handoffs keep migrations comparatively cheap — the
    same asymmetry the paper measures per switch, scaled by a cold-move
    multiplier.
    """
    if n_groups_dest <= 0:
        return 0.0
    st = _DensityProbe(n_groups_dest)
    sibs = np.ones(n_groups_dest)
    c_same = switch_cost_us(
        True, siblings=sibs, groups=n_groups_dest, depth=depth)
    c_cross = switch_cost_us(
        False, siblings=sibs, groups=n_groups_dest, depth=depth)
    p_preempt = min(1.0, max(n_groups_dest - n_cores, 0) / (2.0 * n_cores))
    cost_us, spb = policy.voluntary_switch(
        st, st.th_fn, sibs, c_same, c_cross, c_cross, p_preempt
    )
    return float(np.mean(cost_us)) * 1e-6 * spb * cold_mult


@dataclass(frozen=True)
class Migration:
    """One function moved off a victim node during failover."""

    epoch: int
    fn: int  # global fn id
    src: int
    dst: int
    cost_s: float


@dataclass
class EpochRecord:
    """One controller epoch: what ran, what was lost, what moved."""

    epoch: int
    t0: float
    t1: float
    fleet: FleetResult
    counts: List[int]  # per-node fn counts *during* this epoch
    alive: List[bool]  # ground-truth liveness during this epoch
    detected_dead: List[int] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)
    lost_arrivals: int = 0  # newly stranded this epoch
    replayed: int = 0  # backlog drained into this epoch's offered load
    carried: int = 0  # prior epochs' unfinished work re-offered here
    credited: int = 0  # in-flight work completed from conserved progress
    migrations: int = 0
    migration_s: float = 0.0
    degraded: bool = False
    # topology-aware liveness ladder (all empty on plain crash runs)
    suspects: List[int] = field(default_factory=list)  # detected at t1
    fenced: List[int] = field(default_factory=list)  # fenced *during* epoch
    draining: List[int] = field(default_factory=list)  # trend-drained nodes
    deferred: int = 0  # arrivals deferred off fenced nodes (in lost_arrivals)
    reconciled: int = 0  # completions that landed on fenced nodes


class ChaosFleetResult:
    """A fleet run under fault injection: per-epoch results + failover
    accounting.  Mirrors the :class:`FleetResult` query surface
    (``latencies`` / ``pct`` / ``n_arrived`` / ``n_completed``) so SLO
    checks run unchanged on faulted runs."""

    def __init__(self, policy: str, placement: str,
                 schedule: FaultSchedule, epochs: List[EpochRecord],
                 migrations: List[Migration], duration_s: float,
                 epoch_s: float, n_cores: int, n_nodes: int,
                 rebalanced: bool, slo_s: float = 1.0,
                 proactive: bool = False):
        self.policy = policy
        self.placement = placement
        self.schedule = schedule
        self.epochs = epochs
        self.migrations = migrations
        self.duration_s = duration_s
        self.epoch_s = epoch_s
        self.n_cores = n_cores
        self.n_nodes = n_nodes
        self.rebalanced = rebalanced
        self.slo_s = slo_s
        self.proactive = proactive

    # -- FleetResult-compatible queries ------------------------------------
    @property
    def latencies(self) -> np.ndarray:
        xs = [e.fleet.latencies for e in self.epochs
              if len(e.fleet.latencies)]
        return np.concatenate(xs) if xs else np.empty(0)

    @property
    def n_completed(self) -> int:
        """In-epoch completions plus boundary-spanning ones: requests whose
        partial service at an epoch boundary was credited as conserved
        progress completed too — they just have no latency sample."""
        return sum(e.fleet.n_completed + e.credited for e in self.epochs)

    @property
    def stranded_arrivals(self) -> int:
        """Arrivals that hit a dead node and went into the retry backlog."""
        return sum(e.lost_arrivals for e in self.epochs)

    @property
    def replayed_arrivals(self) -> int:
        """Backlog drained back into live epochs after failover/recovery."""
        return sum(e.replayed for e in self.epochs)

    @property
    def lost_arrivals(self) -> int:
        """Stranded arrivals never replayed — demand lost for good (a
        static placement never drains a crashed node's backlog)."""
        return self.stranded_arrivals - self.replayed_arrivals

    @property
    def carried_arrivals(self) -> int:
        """Unfinished work re-offered across epoch boundaries (each
        carried arrival is re-counted by the epoch it re-enters)."""
        return sum(e.carried for e in self.epochs)

    @property
    def credited_arrivals(self) -> int:
        """Boundary-spanning requests completed from conserved partial
        progress rather than re-served from scratch."""
        return sum(e.credited for e in self.epochs)

    @property
    def deferred_arrivals(self) -> int:
        """Arrivals deferred off fenced (SUSPECT) nodes into the backlog
        — a subset of ``stranded_arrivals``; replayed on heal."""
        return sum(e.deferred for e in self.epochs)

    @property
    def reconciled_completions(self) -> int:
        """Completions that landed on fenced nodes — work the controller
        could not route to but still observed and credits (reconciliation
        of a partitioned-but-alive node's progress)."""
        return sum(e.reconciled for e in self.epochs)

    @property
    def n_arrived(self) -> int:
        """Served arrivals plus the backlog still stranded at run end —
        an unrecovered outage is demand the fleet failed to see.  Carried
        re-offers are netted out so a request that spans epoch boundaries
        counts as one arrival."""
        return sum(e.fleet.n_arrived for e in self.epochs) \
            + self.lost_arrivals - self.carried_arrivals

    @property
    def done_ratio(self) -> float:
        return self.n_completed / max(self.n_arrived, 1)

    def pct(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if len(lat) else float("nan")

    @property
    def migration_s(self) -> float:
        return sum(m.cost_s for m in self.migrations)

    def cumulative_completions(self) -> List[int]:
        out, tot = [], 0
        for e in self.epochs:
            tot += e.fleet.n_completed + e.credited
            out.append(tot)
        return out

    def per_epoch_counts(self) -> List[List[int]]:
        return [list(e.counts) for e in self.epochs]

    def per_epoch_liveness(self) -> List[Dict[str, int]]:
        """Controller-view liveness ladder per epoch: ground-truth live
        nodes, and how many the controller held as suspect / fenced /
        draining — the trajectory the topology fingerprint pins."""
        return [
            {
                "live": int(sum(e.alive)),
                "suspect": len(e.suspects),
                "fenced": len(e.fenced),
                "draining": len(e.draining),
            }
            for e in self.epochs
        ]

    # -- failover metrics --------------------------------------------------
    def _crashed_nodes(self) -> List[Tuple[int, float]]:
        """(node, crash time) for every node a ``node_crash`` or expanded
        ``rack_crash`` event takes down."""
        out: List[Tuple[int, float]] = []
        topo = self.schedule.topology
        for ev in self.schedule.events:
            if ev.kind == "node_crash":
                out.append((ev.node, ev.t))
            elif ev.kind == "rack_crash" and topo is not None:
                out.extend((n, ev.t) for n in topo.nodes_in(ev.rack))
        return out

    def recovery_s(self) -> Dict[int, Optional[float]]:
        """Per crashed node (including nodes taken down by a rack-scoped
        crash): seconds from the crash event until every function it held
        was being served on a live node again (``None`` = never recovered
        within the run)."""
        out: Dict[int, Optional[float]] = {}
        for node, ct in self._crashed_nodes():
            out[node] = None
            for e in self.epochs:
                if e.t1 <= ct:
                    continue
                # recovered in the first epoch where node holds no
                # functions while dead (all re-placed), or is alive again
                held = e.counts[node]
                if (held == 0 and not e.alive[node]) or e.alive[node]:
                    out[node] = max(e.t0 - ct, 0.0)
                    break
        return out

    def max_recovery_s(self) -> Optional[float]:
        """Worst-case per-node recovery, ``None`` when any crashed node
        never recovered (or no node crashed at all)."""
        rec = self.recovery_s()
        if not rec or any(v is None for v in rec.values()):
            return None
        return max(rec.values())

    def degraded_slo_attainment(self, slo_s: Optional[float] = None) -> float:
        """Inside degraded windows (epochs with an active fault or
        stranded work): completions within the SLO / total demand
        (served + stranded arrivals).  NaN when no epoch was degraded."""
        slo = self.slo_s if slo_s is None else slo_s
        ok = arrived = 0
        for e in self.epochs:
            if not e.degraded:
                continue
            lat = e.fleet.latencies
            ok += int(np.sum(lat <= slo)) if len(lat) else 0
            arrived += e.fleet.n_arrived + e.lost_arrivals - e.carried
        return ok / arrived if arrived else float("nan")

    def merged_sched(self) -> SchedStats:
        """Fleet-wide schedstats across all epochs, with every migration
        charged as switch overhead against the moved function."""
        out = SchedStats(f"chaos.{self.policy}.{self.placement}")
        for e in self.epochs:
            out.merge(e.fleet.merged_sched())
        for m in self.migrations:
            out.account_switch(m.fn, m.cost_s)
        return out

    def report(self) -> dict:
        """The failover summary ``repro.obs.report`` renders as its
        ``failover:`` section."""
        rec = self.recovery_s()
        return {
            "events": [ev.to_dict() for ev in self.schedule.events],
            "epochs": len(self.epochs),
            "epoch_s": self.epoch_s,
            "rebalanced": self.rebalanced,
            "crashes": sum(1 for ev in self.schedule.events
                           if ev.kind == "node_crash"),
            "rack_crashes": sum(1 for ev in self.schedule.events
                                if ev.kind == "rack_crash"),
            "partitions": sum(1 for ev in self.schedule.events
                              if ev.kind == "partition"),
            "migrations": len(self.migrations),
            "migration_s": round(self.migration_s, 6),
            "stranded_arrivals": self.stranded_arrivals,
            "replayed_arrivals": self.replayed_arrivals,
            "carried_arrivals": self.carried_arrivals,
            "credited_arrivals": self.credited_arrivals,
            "lost_arrivals": self.lost_arrivals,
            "deferred_arrivals": self.deferred_arrivals,
            "reconciled": self.reconciled_completions,
            "completed": self.n_completed,
            "arrived": self.n_arrived,
            "done_ratio": round(self.done_ratio, 6),
            "recovery_s": {str(k): v for k, v in rec.items()},
            "degraded_slo_attainment": self.degraded_slo_attainment(),
            "stragglers_drained": sorted(
                {s for e in self.epochs for s in e.stragglers}),
            "suspect_nodes": sorted(
                {s for e in self.epochs for s in e.suspects}),
            "fenced_nodes": sorted(
                {s for e in self.epochs for s in e.fenced}),
            "drained_nodes": sorted(
                {s for e in self.epochs for s in e.draining}),
            "proactive_drain": self.proactive,
            "per_epoch_counts": self.per_epoch_counts(),
            "per_epoch_liveness": self.per_epoch_liveness(),
        }


def _node_service_time(r) -> Optional[float]:
    """Observable the watchdog consumes: mean per-request CPU seconds
    (busy / completed) — tracks a node's slowdown factor but, unlike
    latency, is insensitive to queueing, so a node that merely *inherited*
    migrated load is not misflagged as degraded."""
    if r.n_completed <= 0:
        return None
    return r.busy_time_s / r.n_completed


def _count_arrivals(rates: np.ndarray, fn_ids: np.ndarray,
                    duration_s: float, n_cores: int,
                    seed: int, exec_s: float,
                    cache: Dict[Tuple, np.ndarray],
                    extra: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-function arrivals the given functions would have offered (the
    demand stranded on a dead node) — same rate-based, per-function-seeded
    synthesiser the live nodes use, so the stranded counts equal exactly
    what a fault-free run would have served for those functions."""
    fn_ids = np.asarray(fn_ids, np.int64)
    key = (hash(np.asarray(rates).tobytes()), hash(fn_ids.tobytes()),
           round(duration_s, 9), seed,
           None if extra is None
           else hash(np.asarray(extra, np.int64).tobytes()))
    if key not in cache:
        wl = make_workload(
            "azure2021", len(rates), duration_s=duration_s,
            n_cores=n_cores, seed=seed, exec_s=exec_s, rates=rates,
            fn_ids=fn_ids, extra=extra,
        )
        cache[key] = np.asarray([len(a) for a in wl.arrivals], np.int64)
    return cache[key]


def _replace_victims(
    asg: Assignment,
    victims: List[int],
    dests: List[int],
    strategy: str,
    policy: Policy,
    n_cores: int,
    epoch: int,
    depth: float = 5.0,
    cold_mult: float = MIGRATION_COLD_MULT,
    racks: Optional[np.ndarray] = None,
) -> Tuple[Assignment, List[Migration]]:
    """Re-place every function held by ``victims`` onto ``dests`` via the
    placement registry, warm-started with the survivors' current load.
    ``racks`` (per-node, global index space) gives rack-aware strategies
    their failure domains, remapped onto the destination list."""
    victim_fns = np.concatenate(
        [np.asarray(asg.node_fns[v], np.int64) for v in victims])
    src_of = {int(f): v for v in victims for f in asg.node_fns[v]}
    strat = PLACEMENTS[strategy]
    init_load = np.asarray(
        [float(asg.shares[asg.node_fns[d]].sum()) for d in dests])
    init_groups = np.asarray([len(asg.node_fns[d]) for d in dests], np.int64)
    local = strat(
        asg.shares[victim_fns], len(dests), policy=policy, n_cores=n_cores,
        init_load=init_load, init_groups=init_groups,
        racks=None if racks is None else np.asarray(racks, np.int64)[dests],
    )
    node_fns = [np.asarray(f, np.int64) for f in asg.node_fns]
    for v in victims:
        node_fns[v] = np.empty(0, np.int64)
    migrations: List[Migration] = []
    for j, d in enumerate(dests):
        moved = victim_fns[np.asarray(local[j], np.int64)]
        if not len(moved):
            continue
        node_fns[d] = np.sort(np.concatenate([node_fns[d], moved]))
        cost = migration_cost_s(
            policy, len(node_fns[d]), n_cores, depth, cold_mult)
        for f in moved:
            migrations.append(
                Migration(epoch, int(f), src_of[int(f)], d, cost))
    new_asg = Assignment(
        placement=asg.placement, node_fns=tuple(node_fns), shares=asg.shares
    )  # __post_init__ re-checks conservation: every fn on exactly one node
    return new_asg, migrations


def simulate_fleet_chaos(
    policy_name: str,
    assignment: Assignment,
    schedule: FaultSchedule,
    duration_s: float = 30.0,
    epoch_s: Optional[float] = None,
    n_cores: int = 12,
    seed: int = 7,
    exec_s: float = 0.2,
    backend: str = "numpy",
    distinct_seeds: bool = False,
    threads_per_fn: int = 0,
    rebalance: bool = True,
    rebalance_placement: Optional[str] = None,
    health_timeout_s: Optional[float] = None,
    watchdog_warmup: int = 2,
    watchdog_k_sigma: float = 3.0,
    migration_cold_mult: float = MIGRATION_COLD_MULT,
    slo_s: float = 1.0,
    carry_unfinished: bool = True,
    record_dir: Optional[str] = None,
    topology: Optional[Topology] = None,
    proactive_drain: bool = False,
    drain_enter_ratio: float = 1.6,
    drain_exit_ratio: float = 1.2,
    drain_persist: int = 2,
) -> ChaosFleetResult:
    """Run a placed fleet under a fault schedule; see the module docstring.

    With an empty ``schedule`` and no ``epoch_s`` override this delegates
    straight to :func:`simulate_fleet` — bit-identical results, one epoch.
    Otherwise the run is split into ``epoch_s`` controller epochs (default
    ``duration_s / 12``); events snap to the start of the epoch they fall
    in.  ``rebalance=False`` keeps the detection stack running but never
    re-places — the static-placement baseline.  Arrivals stranded on dead
    nodes accumulate in a retry backlog and are replayed in the first
    epoch their function is live again; with a static placement a crashed
    node's backlog is never drained (reported as ``lost_arrivals``).

    ``carry_unfinished`` keeps epoch boundaries work-conserving: a live
    node's admitted-but-uncompleted arrivals re-enter the next epoch's
    offered load (see the module docstring).  Disable it to get
    memoryless epochs, e.g. to observe one epoch's nominal demand in
    isolation.

    ``topology`` (defaults to ``schedule.topology``) enables rack-scoped
    events and rack-avoiding failover; ``proactive_drain`` turns on the
    :class:`TrendDetector` drain loop with hysteresis knobs
    ``drain_enter_ratio`` / ``drain_exit_ratio`` / ``drain_persist`` (see
    the module docstring for the suspect/fenced/drain semantics).
    """
    if schedule.n_nodes != assignment.n_nodes:
        raise ValueError(
            f"schedule is for {schedule.n_nodes} nodes, assignment has "
            f"{assignment.n_nodes}")
    n_nodes = assignment.n_nodes
    if topology is None:
        topology = schedule.topology
    if topology is not None and topology.n_nodes != n_nodes:
        raise ValueError(
            f"topology covers {topology.n_nodes} nodes, assignment has "
            f"{n_nodes}")

    if not schedule and epoch_s is None:
        fleet = simulate_fleet(
            policy_name, assignment, duration_s=duration_s, n_cores=n_cores,
            seed=seed, exec_s=exec_s, backend=backend,
            distinct_seeds=distinct_seeds, threads_per_fn=threads_per_fn,
        )
        res = ChaosFleetResult(
            policy_name, assignment.placement, schedule,
            [EpochRecord(0, 0.0, duration_s, fleet,
                         assignment.counts.tolist(), [True] * n_nodes)],
            [], duration_s, duration_s, n_cores, n_nodes,
            rebalanced=rebalance, slo_s=slo_s,
        )
        if record_dir:
            record_chaos(res, record_dir)
        return res

    epoch_s = epoch_s or duration_s / 12.0
    policy = make_policy(policy_name)
    # each function's actual request rate, recovered from the assignment's
    # reserved shares (shares = rates * exec_s / n_cores): epoch workloads
    # are generated from the functions *assigned* to each node, so a
    # migration moves real demand mass — the count-based band model would
    # regenerate survivors' workloads without the moved functions' rates
    global_rates = assignment.shares * n_cores / exec_s
    reb_name = rebalance_placement or (
        assignment.placement if assignment.placement in PLACEMENTS
        else "spread")
    tracker = HealthTracker(
        n_nodes,
        timeout_s=(health_timeout_s if health_timeout_s is not None
                   else 0.9 * epoch_s),
    )
    for i in range(n_nodes):
        tracker.register(i, now=0.0)
    watchdog = StragglerWatchdog(
        n_nodes, warmup=watchdog_warmup, k_sigma=watchdog_k_sigma)
    trend = TrendDetector(
        n_nodes, enter_ratio=drain_enter_ratio, exit_ratio=drain_exit_ratio,
        persist=drain_persist)
    state = NodeState(n_nodes)
    quarantined: set = set()  # drained stragglers stay out of rotation
    fenced: set = set()  # SUSPECT nodes: alive by evidence, unreachable
    asg = assignment
    epochs: List[EpochRecord] = []
    migrations: List[Migration] = []
    arr_cache: Dict[Tuple, np.ndarray] = {}
    tracing = obs_tracing.active()
    # per-function retry backlog: arrivals stranded on dead nodes, replayed
    # in the first epoch the function is live again (re-placed or recovered)
    backlog = np.zeros(len(assignment.shares), np.int64)
    # per-function carryover: admitted-but-unfinished arrivals from the
    # previous epoch, re-offered wherever the function lives next
    carry = np.zeros(len(assignment.shares), np.int64)
    # the heartbeat network: in-flight heartbeats as (arrive_t, node,
    # sent_t) — a delivered heartbeat proves the node was alive at *send*
    # time, so ``hb_delay`` makes a live node's evidence stale (SUSPECT)
    # without faking freshness.  The loss RNG is only ever drawn for nodes
    # under an active ``heartbeat_loss`` event, so fault-free and
    # crash-only runs consume no randomness (bit-compat with the pinned
    # failover fingerprint).
    hb_pending: List[Tuple[float, int, float]] = []
    hb_rng = np.random.default_rng(np.random.SeedSequence([seed, 0x4Bb]))

    t0 = 0.0
    epoch = 0
    while t0 < duration_s - 1e-9:
        eps = min(epoch_s, duration_s - t0)
        t1 = t0 + eps
        seed_e = seed + _EPOCH_SEED_STRIDE * epoch

        # 1. inject: events in [t0, t1) fire at epoch start
        for ev in schedule.events_in(t0, t1):
            state.apply(ev, topology)
            obs_metrics.counter(f"chaos.{ev.kind}").inc()
            if tracing:
                obs_tracing.tracer().emit(
                    f"fault.{ev.kind}", "chaos", t0 * 1e6, 0.0,
                    {"node": ev.node, "factor": ev.factor,
                     "scheduled_t": ev.t}, ph="i",
                )

        # 2. observe: simulate the epoch over the live nodes.  Offered
        # load follows the assigned functions' rates (storms scale the
        # arrival rate fleet-wide); node slowdowns scale service time.
        # A live node also drains its functions' retry backlog and epoch
        # carryover — *known pending requests*, injected as exact-count
        # arrivals spread over the epoch (feeding them back through the
        # bursty rate process would replay a random multiple of the
        # backlog instead of the backlog itself).
        node_rates = []
        node_extra = []
        replayed_e = 0
        carried_e = 0
        deferred_e = 0
        fenced_e = sorted(fenced)  # the fence applied to *this* epoch
        for i in range(n_nodes):
            fns = asg.node_fns[i]
            base = global_rates[fns] * float(state.storm)
            ext = None
            if state.alive[i] and i in fenced and len(fns):
                # fenced (SUSPECT): no new arrivals are routed — the
                # nominal demand is deferred into the retry backlog and
                # replayed when the node heals (or its functions fail
                # over), while the in-flight carryover it already
                # admitted still completes on the node.  Its parked
                # backlog stays parked: replaying it into an unreachable
                # node would lose the replay.
                counts = _count_arrivals(
                    base, fns, eps, n_cores, seed_e, exec_s, arr_cache)
                backlog[fns] += counts
                deferred_e += int(counts.sum())
                cr = carry[fns]
                if cr.any():
                    carried_e += int(cr.sum())
                    ext = cr
                    carry[fns] = 0
                base = np.zeros_like(base)
            elif state.alive[i] and len(fns):
                bl = backlog[fns]
                cr = carry[fns]
                if bl.any() or cr.any():
                    replayed_e += int(bl.sum())
                    carried_e += int(cr.sum())
                    ext = bl + cr
                    backlog[fns] = 0
                    carry[fns] = 0
            node_rates.append(base)
            node_extra.append(ext)
        if replayed_e:
            obs_metrics.counter("chaos.replayed_arrivals").inc(replayed_e)
        if carried_e:
            obs_metrics.counter("chaos.carried_arrivals").inc(carried_e)
        if deferred_e:
            obs_metrics.counter("chaos.deferred_arrivals").inc(deferred_e)
        fleet_e = simulate_fleet(
            policy_name, asg, duration_s=eps, n_cores=n_cores, seed=seed_e,
            exec_s=exec_s, backend=backend, distinct_seeds=distinct_seeds,
            threads_per_fn=threads_per_fn, node_exec_mult=state.slow,
            dead=~state.alive, node_rates=node_rates,
            node_extra=node_extra,
        )

        # stranded demand: functions parked on dead nodes still *arrive* —
        # clients retry, so the counts join the per-function backlog
        lost = 0
        for i in range(n_nodes):
            if not state.alive[i] and len(asg.node_fns[i]):
                counts = _count_arrivals(
                    node_rates[i], asg.node_fns[i], eps, n_cores, seed_e,
                    exec_s, arr_cache,
                )
                backlog[asg.node_fns[i]] += counts
                lost += int(counts.sum())
        if lost:
            obs_metrics.counter("chaos.lost_arrivals").inc(lost)

        # work conservation across the boundary: whatever a live node
        # admitted but did not finish inside this epoch is re-offered in
        # the next one (the arrival counts regenerate deterministically —
        # common random numbers — so arrived - completed is exact).
        # Progress is conserved too: re-serving every carried request
        # from scratch would throw away the partial service it received
        # before the boundary — a restart tax proportional to in-flight
        # inventory, which compounds into runaway backlog on exactly the
        # loaded post-failover survivors the comparison is about.  The
        # aggregate partial work (busy seconds beyond completed-request
        # cost, in request-equivalents) is therefore credited against the
        # carried counts: those requests complete from conserved progress
        # and are counted as boundary-spanning completions (no latency
        # sample — their latency straddles two epochs).
        credited_e = 0
        if carry_unfinished:
            for i in range(n_nodes):
                fns = asg.node_fns[i]
                if not state.alive[i] or not len(fns):
                    continue
                r = fleet_e.nodes[i]
                arr = _count_arrivals(
                    node_rates[i], fns, eps, n_cores, seed_e, exec_s,
                    arr_cache, extra=node_extra[i],
                )
                done = np.bincount(
                    np.asarray(r.fn_of, np.int64), minlength=len(fns),
                )[:len(fns)]
                unfinished = np.maximum(arr - done, 0)
                equiv = int(r.busy_time_s
                            / (exec_s * float(state.slow[i]))) \
                    - int(done.sum())
                for f in np.argsort(-unfinished):
                    if equiv <= 0 or unfinished[f] == 0:
                        break
                    take = min(int(unfinished[f]), equiv)
                    unfinished[f] -= take
                    equiv -= take
                    credited_e += take
                carry[fns] += unfinished
        if credited_e:
            obs_metrics.counter("chaos.credited_arrivals").inc(credited_e)

        # evidence + detection: observed completions are progress evidence
        # (they land in shared results, so they survive partitions) and
        # heartbeats ride the modelled network — sent a few times per
        # epoch (real heartbeat cadence is finer than the control
        # interval; with a single send at the epoch end, any sub-epoch
        # delivery delay would quantize up to a full epoch of staleness
        # and trip the detector) unless the node is partitioned at send
        # time or the seeded loss drops them, delivered once their delay
        # elapses, and timestamped at *send* time (a late heartbeat
        # proves the node was alive when it sent, not now — that
        # staleness is exactly what makes it SUSPECT).  Routed-work notes
        # tell the tracker which silences it may hold against a host:
        # fenced nodes get nothing routed, so their progress silence is
        # the controller's own doing and must not escalate to failure.
        reconciled_e = 0
        stragglers: List[int] = []
        hb_times = [t0 + eps * k / HB_PER_EPOCH
                    for k in range(1, HB_PER_EPOCH + 1)]
        part_at = [state.partitioned(ts) for ts in hb_times]
        for i in range(n_nodes):
            if i not in fenced and len(asg.node_fns[i]):
                tracker.note_routed(i, now=t1)
        for i in range(n_nodes):
            if not state.alive[i]:
                continue
            r = fleet_e.nodes[i]
            if r.n_completed > 0:
                tracker.observe_progress(i, now=t1)
                if i in fenced:
                    reconciled_e += int(r.n_completed)
            for ts, part in zip(hb_times, part_at):
                if part[i]:
                    continue
                p_loss = float(state.hb_loss[i])
                if p_loss <= 0.0 or hb_rng.random() >= p_loss:
                    hb_pending.append(
                        (ts + float(state.hb_delay[i]), i, ts))
            svc = _node_service_time(r)
            if svc is not None and watchdog.observe(i, svc):
                if i not in quarantined:
                    stragglers.append(i)
        if reconciled_e:
            obs_metrics.counter("chaos.reconciled").inc(reconciled_e)
        still_pending: List[Tuple[float, int, float]] = []
        for arrive_t, node, sent_t in hb_pending:
            if arrive_t <= t1 + 1e-9:
                # never let an older in-flight heartbeat regress the
                # freshness a newer (faster) one already established
                if tracker.last_seen.get(node, -1e18) < sent_t:
                    tracker.heartbeat(node, now=sent_t)
            else:
                still_pending.append((arrive_t, node, sent_t))
        hb_pending = still_pending
        detected_dead = tracker.failed_hosts(now=t1)
        suspects = tracker.suspect_hosts(now=t1)

        # proactive drain: trend-detect nodes drifting away from the
        # healthy-fleet service time and migrate their load *before* the
        # watchdog quarantines them.  Idle nodes (no completions — e.g.
        # already fully drained) are observed through a synthetic probe
        # at the node's slowdown multiplier, the sim stand-in for a real
        # drainer's probe requests — without it a drained node could
        # never demonstrate recovery and the hysteresis could not exit.
        draining_now: List[int] = []
        if proactive_drain:
            for i in range(n_nodes):
                if not state.alive[i]:
                    trend.forget(i)
                    continue
                if i in quarantined:
                    continue
                svc = _node_service_time(fleet_e.nodes[i])
                if svc is None:
                    svc = exec_s * float(state.slow[i])
                trend.observe(i, svc)
            draining_now = [i for i in trend.drain_hosts()
                            if i not in quarantined]

        degraded = bool(
            lost or deferred_e or replayed_e or detected_dead or suspects
            or fenced_e or draining_now or stragglers or quarantined
            or (~state.alive).any() or (state.slow > 1.0).any()
            or state.storm > 1.0
        )
        rec = EpochRecord(
            epoch, t0, t1, fleet_e, asg.counts.tolist(),
            state.alive.tolist(), list(detected_dead), stragglers,
            lost + deferred_e,
            replayed=replayed_e, carried=carried_e, credited=credited_e,
            degraded=degraded, suspects=list(suspects), fenced=fenced_e,
            draining=draining_now, deferred=deferred_e,
            reconciled=reconciled_e,
        )
        # the fence follows the *latest* suspicion verdict: newly suspect
        # nodes stop receiving work next epoch, healed nodes (heartbeats
        # flowing again) are unfenced and their deferred backlog replays
        fenced = set(suspects)

        # 3./4. re-place the victims' functions and charge the migrations.
        # Fenced nodes are neither victims nor destinations: their work is
        # not failed over (that would double-place a probably-alive node's
        # functions) and no new load lands on them.  Trend-drained nodes
        # *are* victims — their load migrates early at the priced cost —
        # but unlike quarantine the drain is reversible: once the trend
        # detector's hysteresis exits, the node rejoins the destinations.
        if rebalance:
            quarantined |= set(stragglers)
            unavailable = set(detected_dead) | quarantined
            drain_set = set(draining_now)
            victims = sorted(
                v for v in (unavailable | drain_set) - fenced
                if len(asg.node_fns[v])
            )
            dests = [d for d in range(n_nodes)
                     if d not in unavailable | drain_set | fenced]
            if topology is not None and dests:
                # steer failover traffic out of failing racks: a rack with
                # a confirmed-dead member is suspect as a domain (shared
                # power/ToR), so prefer destinations elsewhere — a soft
                # constraint, waived when every surviving node shares a
                # failing rack
                bad_racks = {topology.rack_of(v) for v in detected_dead}
                safe = [d for d in dests
                        if topology.rack_of(d) not in bad_racks]
                if safe:
                    dests = safe
            if victims and dests:
                asg, moved = _replace_victims(
                    asg, victims, dests, reb_name, policy, n_cores, epoch,
                    cold_mult=migration_cold_mult,
                    racks=None if topology is None else topology.racks(),
                )
                migrations.extend(moved)
                rec.migrations = len(moved)
                rec.migration_s = sum(m.cost_s for m in moved)
                obs_metrics.counter("chaos.migrations").inc(len(moved))
                if tracing:
                    obs_tracing.tracer().emit(
                        "rebalance.migrate", "chaos", t1 * 1e6, 0.0,
                        {"victims": victims, "moved": len(moved),
                         "cost_s": rec.migration_s}, ph="i",
                    )
                # every live function on exactly one live node (the
                # Assignment already guarantees exactly-one-node overall)
                for v in victims:
                    assert len(asg.node_fns[v]) == 0, (
                        f"victim node {v} still holds functions")

        epochs.append(rec)
        t0 = t1
        epoch += 1

    res = ChaosFleetResult(
        policy_name, assignment.placement, schedule, epochs, migrations,
        duration_s, epoch_s, n_cores, n_nodes, rebalanced=rebalance,
        slo_s=slo_s, proactive=proactive_drain,
    )
    if record_dir:
        record_chaos(res, record_dir)
    return res


def record_chaos(res: ChaosFleetResult, out_dir: str) -> List[str]:
    """Persist a chaos run: one merged-over-epochs record per node
    (``node<i>/run.json`` — render with ``repro.obs.report --merge``)
    plus a top-level record carrying the failover report
    (``repro.obs.report out_dir`` shows the ``failover:`` section)."""
    from repro.obs.recorder import record_run

    paths = []
    for i in range(res.n_nodes):
        node_sched = SchedStats(f"chaos.node{i}")
        for e in res.epochs:
            node_sched.merge(e.fleet.nodes[i].sched_summary())
        paths.append(record_run(
            os.path.join(out_dir, f"node{i}"),
            meta={
                "layer": "chaos-fleet", "policy": res.policy,
                "placement": res.placement, "node": i,
                "n_nodes": res.n_nodes, "epochs": len(res.epochs),
                "duration_s": res.duration_s,
            },
            sched=node_sched,
            include_registry=False,
        ))
    paths.append(record_run(
        out_dir,
        meta={
            "layer": "chaos-fleet", "policy": res.policy,
            "placement": res.placement, "n_nodes": res.n_nodes,
            "epochs": len(res.epochs), "duration_s": res.duration_s,
            "rebalance": res.rebalanced,
        },
        sched=res.merged_sched(),
        chaos=res.report(),
        include_registry=False,
    ))
    return paths
