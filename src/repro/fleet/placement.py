"""Placement registry: assign function cgroups to fleet nodes.

The orchestrator-side half of the paper's cluster study (§5.1): before any
node schedules anything, *placement* decides which function lands where.
Each strategy partitions the global function ids ``0..total_fns-1`` —
every function carries a *reserved share* (its band-model mean demand as a
fraction of one node's cores, see :func:`fn_shares`) — into per-node
assignments:

  * ``round-robin``  — fn ``i`` -> node ``i % n_nodes``; band-striped, the
    paper's banded placement (nodes statistically identical).
  * ``pack``         — first-fit decreasing by reserved share against a
    per-node share cap: fills nodes densely, leaves the tail nodes light
    (the consolidation-friendly but switch-hostile extreme; cf. the
    constraint-based pod-packing line of work, arXiv:2511.08373).
  * ``spread``       — least-loaded (LPT greedy): each function goes to the
    node with the smallest reserved-share sum (cf. C-Balancer's
    profile-driven rebalancing, arXiv:2009.08912).
  * ``switch-aware`` — least *cost*: greedy like ``spread``, but the
    objective adds the scheduling-policy voluntary-switch overhead the
    node would pay for one more colocated cgroup, estimated through the
    numpy :class:`repro.sched.numpy_backend.Policy` cost model — dense
    cgroup stacking is penalised super-linearly, and run-to-completion
    policies (LAGS) tolerate density that CFS cannot.
  * ``rack-spread``  — least-loaded node, ties broken toward the
    least-loaded *rack*: balances reserved share like ``spread`` while
    steering equal-load choices across failure domains (pass the per-node
    ``racks`` array from :meth:`repro.fleet.topology.Topology.racks`), so
    a rack-scoped crash strands the smallest possible share and failover
    replicas do not re-concentrate in one domain.  Without ``racks`` it
    degrades to ``spread`` exactly (every node its own rack).

Every strategy must *conserve the function count*: each global fn id is
assigned to exactly one node (``Assignment.__post_init__`` asserts it).
The legacy representative-node path (``core.cluster.simulate_node_share``)
silently floored to ``max(1, total // n_nodes)`` functions per node,
dropping up to ``n_nodes - 1`` functions from the cluster total — the
regression tests in ``tests/test_fleet.py`` pin the fix.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.switch_cost import switch_cost_us
from repro.sched.numpy_backend import Policy, make_policy

PLACEMENTS: Dict[str, Callable] = {}


def fn_shares(
    total_fns: int,
    n_cores: int = 12,
    exec_s: float = 0.2,
    seed: int = 7,
) -> np.ndarray:
    """Per-function reserved share: band-model mean demand / node capacity.

    The same heavy-tailed band rates the workload synthesiser draws from
    (``traces.fn_rates``), converted to the fraction of one node's cores a
    function's mean demand reserves.  Deterministic given ``seed``.
    """
    from repro.core.traces import fn_rates

    rates = fn_rates(total_fns, n_cores, seed)
    return rates * exec_s / n_cores


@dataclass(frozen=True)
class Assignment:
    """A placement decision: global fn ids partitioned over nodes."""

    placement: str
    node_fns: Tuple[np.ndarray, ...]  # per-node global fn ids
    shares: np.ndarray  # (total_fns,) reserved share per global fn

    def __post_init__(self):
        total = int(self.shares.shape[0])
        seen = np.concatenate([np.asarray(f, np.int64) for f in self.node_fns]) \
            if self.node_fns else np.empty(0, np.int64)
        # conservation: every function exactly once — the cluster total
        # must not silently shrink (the old // floor dropped functions)
        assert len(seen) == total and len(np.unique(seen)) == total, (
            f"{self.placement}: assigned {len(seen)} of {total} functions "
            f"({total - len(np.unique(seen))} dropped/duplicated)"
        )

    @property
    def n_nodes(self) -> int:
        return len(self.node_fns)

    @property
    def counts(self) -> np.ndarray:
        return np.asarray([len(f) for f in self.node_fns], np.int64)

    @property
    def node_shares(self) -> np.ndarray:
        return np.asarray([float(self.shares[f].sum()) for f in self.node_fns])

    def share_imbalance(self) -> float:
        """max/mean reserved-share ratio across nodes (1.0 = perfect)."""
        s = self.node_shares
        return float(s.max() / max(s.mean(), 1e-12))


def _register(name: str):
    def deco(fn):
        PLACEMENTS[name] = fn
        return fn
    return deco


@_register("round-robin")
def _round_robin(shares: np.ndarray, n_nodes: int, **_kw) -> List[np.ndarray]:
    total = shares.shape[0]
    return [np.arange(total, dtype=np.int64)[i::n_nodes]
            for i in range(n_nodes)]


@_register("pack")
def _pack(shares: np.ndarray, n_nodes: int, headroom: float = 1.25,
          init_load: Optional[np.ndarray] = None,
          **_kw) -> List[np.ndarray]:
    """First-fit decreasing by reserved share against a per-node cap.

    ``init_load`` warm-starts the per-node loads (mid-run rebalancing:
    survivors already carry their placed share; only the new functions in
    ``shares`` are assigned).
    """
    load = (np.zeros(n_nodes) if init_load is None
            else np.asarray(init_load, float).copy())
    cap = headroom * (shares.sum() + load.sum()) / n_nodes
    out: List[list] = [[] for _ in range(n_nodes)]
    for f in np.argsort(-shares, kind="stable"):
        fits = np.where(load + shares[f] <= cap)[0]
        # overflow (cap too tight for the tail): least-loaded fallback so
        # conservation always holds
        n = int(fits[0]) if len(fits) else int(np.argmin(load))
        out[n].append(int(f))
        load[n] += shares[f]
    return [np.asarray(sorted(g), np.int64) for g in out]


@_register("spread")
def _spread(shares: np.ndarray, n_nodes: int,
            init_load: Optional[np.ndarray] = None,
            **_kw) -> List[np.ndarray]:
    """Least-loaded (LPT greedy) by reserved share.  ``init_load``
    warm-starts per-node loads for mid-run rebalancing."""
    load = (np.zeros(n_nodes) if init_load is None
            else np.asarray(init_load, float).copy())
    out: List[list] = [[] for _ in range(n_nodes)]
    for f in np.argsort(-shares, kind="stable"):
        n = int(np.argmin(load))
        out[n].append(int(f))
        load[n] += shares[f]
    return [np.asarray(sorted(g), np.int64) for g in out]


@_register("rack-spread")
def _rack_spread(shares: np.ndarray, n_nodes: int,
                 racks: Optional[np.ndarray] = None,
                 init_load: Optional[np.ndarray] = None,
                 **_kw) -> List[np.ndarray]:
    """Least-loaded node, least-loaded rack as tiebreak (two-level LPT
    greedy by reserved share).

    ``racks[i]`` is node ``i``'s failure domain (``Topology.racks()``, or
    any subset of it remapped onto a destination list for mid-run
    rebalancing).  ``init_load`` warm-starts per-node loads, and the rack
    loads are derived from it, so failover placement sees the survivors'
    *current* rack occupancy.  With ``racks=None`` every node is its own
    rack and the strategy reduces to ``spread`` exactly.
    """
    load = (np.zeros(n_nodes) if init_load is None
            else np.asarray(init_load, float).copy())
    if racks is None:
        racks = np.arange(n_nodes, dtype=np.int64)
    else:
        racks = np.asarray(racks, np.int64)
        if racks.shape[0] != n_nodes:
            raise ValueError(
                f"racks has {racks.shape[0]} entries for {n_nodes} nodes")
    rack_load = np.zeros(int(racks.max()) + 1)
    np.add.at(rack_load, racks, load)
    out: List[list] = [[] for _ in range(n_nodes)]
    for f in np.argsort(-shares, kind="stable"):
        s = float(shares[f])
        # primary key: the node's own load; secondary: its rack load; ties
        # broken by node index (lexsort is stable).  Node load must lead:
        # were rack load primary, a rack left with a single live node
        # (e.g. its sibling just drained) would have the smallest rack
        # load and swallow an entire failover wave onto that one node —
        # rack diversity is the tiebreak among equally loaded nodes, not
        # an excuse to overload one.
        n = int(np.lexsort((rack_load[racks], load))[0])
        out[n].append(int(f))
        load[n] += s
        rack_load[racks[n]] += s
    return [np.asarray(sorted(g), np.int64) for g in out]


class _DensityProbe:
    """Minimal ``simkernel._State`` facade for ``Policy.voluntary_switch``.

    Models a node at placement time: one representative runnable thread per
    colocated cgroup, uniform Load Credit (steady state), every thread
    waiting — exactly the dense-stacking regime the paper measures.
    """

    def __init__(self, n_groups: int):
        self.credit = np.zeros(n_groups)
        self.th_fn = np.arange(n_groups, dtype=np.int64)
        self._wait = np.ones(n_groups, bool)

    def waiting_mask(self) -> np.ndarray:
        return self._wait


def switch_penalty(
    policy: Policy,
    n_groups: int,
    util: float,
    n_cores: int = 12,
    depth: float = 5.0,
    burst_us: float = 280.0,
) -> float:
    """Estimated voluntary-switch overhead fraction of a node hosting
    ``n_groups`` cgroups at reserved utilisation ``util``.

    Runs the policy's own voluntary-handoff cost model (the same
    ``Policy.voluntary_switch`` the tick simulator charges each tick, §3.2
    steady-state: useful fraction = burst / (burst + spb * cost)) on a
    density probe, so a placement sees CFS's log-growing cross-cgroup cost
    while LAGS's in-order run-to-completion handoffs stay near-free.
    """
    if n_groups <= 0:
        return 0.0
    st = _DensityProbe(n_groups)
    run_fn = st.th_fn
    sibs = np.ones(n_groups)
    c_same = switch_cost_us(True, siblings=sibs, groups=n_groups, depth=depth)
    c_cross = switch_cost_us(False, siblings=sibs, groups=n_groups, depth=depth)
    p_preempt = min(1.0, max(n_groups - n_cores, 0) / (2.0 * n_cores))
    cost_us, spb = policy.voluntary_switch(
        st, run_fn, sibs, c_same, c_cross, c_cross, p_preempt
    )
    cost_s = float(np.mean(cost_us)) * 1e-6 * spb
    burst_s = burst_us * 1e-6
    return min(util, 1.0) * cost_s / (burst_s + cost_s)


@_register("switch-aware")
def _switch_aware(shares: np.ndarray, n_nodes: int,
                  policy: Optional[Policy] = None, n_cores: int = 12,
                  depth: float = 5.0,
                  init_load: Optional[np.ndarray] = None,
                  init_groups: Optional[np.ndarray] = None,
                  **_kw) -> List[np.ndarray]:
    """Greedy least-(load + switch-overhead) placement.  ``init_load`` /
    ``init_groups`` warm-start the survivors' reserved load and colocated
    cgroup counts for mid-run rebalancing, so the switch-cost objective
    prices the *post-migration* density of each candidate node."""
    policy = policy or make_policy("cfs")
    load = (np.zeros(n_nodes) if init_load is None
            else np.asarray(init_load, float).copy())
    groups = (np.zeros(n_nodes, np.int64) if init_groups is None
              else np.asarray(init_groups, np.int64).copy())
    out: List[list] = [[] for _ in range(n_nodes)]
    for f in np.argsort(-shares, kind="stable"):
        s = float(shares[f])
        cost = np.asarray([
            load[n] + s + switch_penalty(
                policy, int(groups[n]) + 1, load[n] + s, n_cores, depth
            )
            for n in range(n_nodes)
        ])
        n = int(np.argmin(cost))
        out[n].append(int(f))
        load[n] += s
        groups[n] += 1
    return [np.asarray(sorted(g), np.int64) for g in out]


def place(
    name: str,
    total_fns: int,
    n_nodes: int,
    shares: Optional[np.ndarray] = None,
    policy: Optional[Policy] = None,
    n_cores: int = 12,
    exec_s: float = 0.2,
    seed: int = 7,
    **kw,
) -> Assignment:
    """Run a registered placement strategy; returns a conservation-checked
    :class:`Assignment`."""
    try:
        strat = PLACEMENTS[name]
    except KeyError:
        raise ValueError(
            f"unknown placement {name!r}; have {sorted(PLACEMENTS)}"
        ) from None
    if shares is None:
        shares = fn_shares(total_fns, n_cores, exec_s, seed)
    node_fns = strat(shares, n_nodes, policy=policy, n_cores=n_cores, **kw)
    return Assignment(placement=name, node_fns=tuple(node_fns), shares=shares)
