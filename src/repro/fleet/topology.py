"""Fleet fault topology: which nodes share a failure domain.

Real failures are correlated: a rack loses power or its ToR switch and
every node in it goes dark together; a zone-level event takes several
racks at once.  The single-node ``node_crash`` grammar cannot express
that, so the chaos layer carries a :class:`Topology` — a node -> rack
mapping (optionally rack -> zone) — that

  * lets :class:`repro.fleet.chaos.FaultSchedule` validate and expand
    rack-scoped events (``rack_crash``),
  * gives the ``rack-spread`` placement strategy its balance domains, and
  * lets the rebalancing controller steer failover traffic *away* from a
    failing rack (replicas of a victim's functions avoid its rack).

Topologies are validated up front (contiguous rack ids, no empty rack),
deterministic, and round-trip through JSON byte-for-byte — the same
replayability contract the fault schedules keep.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np


@dataclass(frozen=True)
class Topology:
    """Node -> rack (and optional rack -> zone) failure-domain mapping."""

    rack_of_node: Tuple[int, ...]  # node i lives in rack rack_of_node[i]
    zone_of_rack: Tuple[int, ...] = ()  # optional: rack r lives in zone [r]

    def __post_init__(self):
        object.__setattr__(
            self, "rack_of_node",
            tuple(int(r) for r in self.rack_of_node))
        object.__setattr__(
            self, "zone_of_rack",
            tuple(int(z) for z in self.zone_of_rack))
        if not self.rack_of_node:
            raise ValueError("topology must cover at least one node")
        racks = sorted(set(self.rack_of_node))
        if racks != list(range(len(racks))):
            raise ValueError(
                f"rack ids must be contiguous 0..{len(racks) - 1} with no "
                f"empty rack, got {racks}")
        if any(r < 0 for r in self.rack_of_node):
            raise ValueError("rack ids must be >= 0")
        if self.zone_of_rack:
            if len(self.zone_of_rack) != len(racks):
                raise ValueError(
                    f"zone_of_rack must have one entry per rack: "
                    f"{len(self.zone_of_rack)} != {len(racks)}")
            zones = sorted(set(self.zone_of_rack))
            if zones != list(range(len(zones))):
                raise ValueError(
                    f"zone ids must be contiguous 0..{len(zones) - 1}, "
                    f"got {zones}")

    # -- construction ------------------------------------------------------
    @classmethod
    def uniform(cls, n_nodes: int, rack_size: int,
                zone_racks: int = 0) -> "Topology":
        """``rack_size`` consecutive nodes per rack (last rack may be
        short); with ``zone_racks`` > 0, that many consecutive racks per
        zone."""
        if rack_size <= 0:
            raise ValueError("rack_size must be positive")
        rack_of = tuple(i // rack_size for i in range(int(n_nodes)))
        zones: Tuple[int, ...] = ()
        if zone_racks > 0:
            n_racks = max(rack_of) + 1
            zones = tuple(r // zone_racks for r in range(n_racks))
        return cls(rack_of, zones)

    @classmethod
    def flat(cls, n_nodes: int) -> "Topology":
        """Every node its own rack — no correlated failure domains (the
        degenerate topology, equivalent to having none)."""
        return cls(tuple(range(int(n_nodes))))

    # -- queries -----------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self.rack_of_node)

    @property
    def n_racks(self) -> int:
        return max(self.rack_of_node) + 1

    @property
    def n_zones(self) -> int:
        return (max(self.zone_of_rack) + 1) if self.zone_of_rack else 0

    def rack_of(self, node: int) -> int:
        return self.rack_of_node[node]

    def zone_of(self, rack: int) -> int:
        return self.zone_of_rack[rack] if self.zone_of_rack else 0

    def nodes_in(self, rack: int) -> List[int]:
        if not (0 <= rack < self.n_racks):
            raise ValueError(
                f"rack {rack} out of range [0, {self.n_racks})")
        return [i for i, r in enumerate(self.rack_of_node) if r == rack]

    def racks(self) -> np.ndarray:
        """Per-node rack ids as an array (placement strategies consume
        this rather than the object, so mid-run rebalancing can remap a
        survivors-only node subset)."""
        return np.asarray(self.rack_of_node, np.int64)

    def rack_members(self) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {r: [] for r in range(self.n_racks)}
        for i, r in enumerate(self.rack_of_node):
            out[r].append(i)
        return out

    # -- replayable serialisation -----------------------------------------
    def to_obj(self) -> dict:
        obj = {"rack_of_node": list(self.rack_of_node)}
        if self.zone_of_rack:
            obj["zone_of_rack"] = list(self.zone_of_rack)
        return obj

    def to_json(self) -> str:
        return json.dumps(self.to_obj(), sort_keys=True,
                          separators=(",", ":"))

    @classmethod
    def from_obj(cls, obj: dict) -> "Topology":
        return cls(tuple(obj["rack_of_node"]),
                   tuple(obj.get("zone_of_rack", ())))

    @classmethod
    def from_json(cls, text: str) -> "Topology":
        return cls.from_obj(json.loads(text))
