"""Paged KV-cache manager for the multi-tenant serving engine.

Pages are fixed-size blocks of KV slots (default 128 tokens).  The page table
is host-side (numpy) — allocation/free is control-plane work; the device-side
cache is the dense per-layer tensor managed by ``repro.models`` with slot
indices assigned here.  The LAGS admission scheduler charges each tenant for
resident pages; evicting a tenant releases its pages (this is the engine's
"context switch" cost accounted in ``engine.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class PagedAllocator:
    n_pages: int
    page_tokens: int = 128

    def __post_init__(self):
        self.free_list: List[int] = list(range(self.n_pages))
        self.owner: Dict[int, list] = {}  # seq_id -> pages

    @property
    def free_pages(self) -> int:
        return len(self.free_list)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_tokens)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= self.free_pages

    def allocate(self, seq_id: int, n_tokens: int) -> Optional[np.ndarray]:
        need = self.pages_for(n_tokens)
        if need > self.free_pages:
            return None
        pages = [self.free_list.pop() for _ in range(need)]
        self.owner.setdefault(seq_id, []).extend(pages)
        return np.asarray(pages, np.int32)

    def extend(self, seq_id: int, cur_tokens: int, new_tokens: int):
        """Grow a sequence; returns newly allocated pages (may be empty)."""
        have = len(self.owner.get(seq_id, [])) * self.page_tokens
        need = self.pages_for(cur_tokens + new_tokens) - len(
            self.owner.get(seq_id, [])
        )
        if need <= 0:
            return np.empty(0, np.int32)
        if need > self.free_pages:
            return None
        pages = [self.free_list.pop() for _ in range(need)]
        self.owner[seq_id].extend(pages)
        del have
        return np.asarray(pages, np.int32)

    def free(self, seq_id: int) -> int:
        pages = self.owner.pop(seq_id, [])
        self.free_list.extend(pages)
        return len(pages)

    def utilization(self) -> float:
        return 1.0 - self.free_pages / self.n_pages
