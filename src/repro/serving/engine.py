"""Multi-tenant continuous-batching engine with LAGS admission.

The TPU-native integration of the paper (DESIGN.md §2): many function-like
tenants share one serving slice; every engine step decodes one token for each
running request (plus chunked prefills for newly admitted ones).  Changing
batch *membership* is the engine's context switch — it costs weight/adapter
HBM swaps, KV-page (re)allocation and dispatch overhead, and its frequency
and cost grow with tenant colocation exactly like ``schedule()`` in §3 of
the paper.  LAGS admission (lowest Load Credit, run-to-completion) reduces
both the rate and the per-switch cost versus fair round-robin admission.

Two execution backends:
  * ``step_cost_model`` (default) — calibrated analytic step times (CPU-fast;
    used by benchmarks to sweep density like Fig 3/9).
  * a real jitted ``decode_step`` over a reduced model (``attach_model``) —
    used by tests/examples to prove the engine drives real compute.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import tracing as obs_tracing
from repro.obs.schedstats import SchedStats
from repro.sched import pallas_backend
from repro.scheduler.admission import pick_admissions, should_preempt
from repro.scheduler.tenant import Request, Tenant
from repro.serving.kvcache import PagedAllocator


@dataclass
class EngineConfig:
    n_slots: int = 16  # concurrent decode streams
    n_pages: int = 4096
    page_tokens: int = 128
    policy: str = "lags"  # any repro.sched.serving admission policy
    # LAGS preemption hysteresis (repro.sched.protocol.credit_preempt): a
    # waiting tenant evicts a running one only when its credit is below
    # hysteresis * victim_credit.  The engine default demands a clear gap
    # (0.5) because a batch membership change is far costlier than the
    # kernel task switch the node simulators model with hysteresis 1.0.
    preempt_hysteresis: float = 0.5
    # route the per-step Load-Credit tick through the fused Pallas kernel
    # (repro.sched.pallas_backend) once the tenant count reaches this
    # threshold; 0 disables the kernel path entirely
    pallas_threshold: int = 256
    # step cost model (seconds)
    base_step_s: float = 0.010  # one decode step for a full batch
    per_prefill_tok_s: float = 2.0e-6
    swap_s_per_mb: float = 0.2e-3  # HBM weight/adapter swap on residency miss
    dispatch_s_per_member_change: float = 0.4e-3  # batch re-formation
    max_resident: int = 24  # tenants whose weights fit in HBM (LRU)
    credit_window: int = 256
    # -- graceful degradation (each knob 0 = off) ------------------------
    # admission deadline: a request still queued (never admitted) this many
    # sim-seconds after arrival is expired instead of served late
    admission_timeout_s: float = 0.0
    # out-of-pages rejections park the request with exponential backoff
    # (base * 2**(rejections-1), capped) instead of silently re-queueing it
    # at the head where it re-fails every step
    backoff_base_s: float = 0.02
    backoff_max_s: float = 0.5
    # overload shedding: when total queued work (tenant queues + parked)
    # exceeds the watermark, shed from the *highest-credit* tenants — the
    # most-served, i.e. lowest-priority work under LAGS admission (the
    # issue's "lowest-credit work" in admission-order terms: the work
    # admitted last).  ``drop`` discards newest requests; ``truncate``
    # halves ``max_new`` once per request instead of dropping.
    shed_watermark: int = 0
    shed_mode: str = "drop"  # "drop" | "truncate"


class EngineStats:
    """Engine accounting, backed by ``repro.obs.schedstats.SchedStats``.

    The old ad-hoc fields survive as views onto the schedstats so existing
    callers (benchmarks, examples) keep working; the full per-tenant
    breakdown, latency/run-delay histograms and run-queue timeline live on
    ``.sched`` and are what ``repro.obs.report`` consumes.
    """

    def __init__(self):
        self.sched = SchedStats("engine")
        self.time_s = 0.0
        self.steps = 0
        self.completed: List[Request] = []
        # graceful-degradation counters (also published as obs metrics
        # ``engine.shed`` / ``engine.expired`` / ``engine.backoff``)
        self.shed = 0
        self.expired = 0
        self.backoffs = 0
        # fencing counters (``engine.fenced_steps`` / ``engine.deferred``):
        # steps taken while fenced, and requests that arrived during a
        # fence — queued for later, not admitted (reconciled on unfence)
        self.fenced_steps = 0
        self.deferred = 0

    @property
    def fenced_s(self) -> float:
        return self.sched.fenced_s

    @property
    def useful_s(self) -> float:
        return self.sched.useful_s

    @property
    def switch_s(self) -> float:
        return self.sched.switch_s

    @property
    def membership_changes(self) -> int:
        return int(self.sched.switches)

    @property
    def overhead_frac(self) -> float:
        return self.switch_s / max(self.time_s, 1e-12)


class Engine:
    def __init__(self, cfg: EngineConfig, tenants: Dict[int, Tenant]):
        self.cfg = cfg
        self.tenants = tenants
        self.alloc = PagedAllocator(cfg.n_pages, cfg.page_tokens)
        self.running: List[Request] = []
        self.stats = EngineStats()
        self._prev_members: set = set()
        self._resident: List[int] = []  # LRU order, most recent last
        self._parked: List[Request] = []  # backing off after page rejection
        self._fenced = False
        self._model = None

    # -- fencing ----------------------------------------------------------
    # The serving-side half of the controller's SUSPECT tier: while a node
    # is suspected (heartbeats overdue, progress still observed) no new
    # work is admitted, in-flight requests run to completion, and arrivals
    # queue up to reconcile once the fence lifts — the engine is *drained
    # of admissions*, not killed, so nothing is double-placed elsewhere.
    def fence(self):
        if not self._fenced:
            self._fenced = True
            obs_metrics.counter("engine.fence").inc()
            if obs_tracing.active():
                obs_tracing.tracer().emit(
                    "engine.fence", "engine", self.stats.time_s * 1e6, 0.0,
                    {"queued": sum(len(t.queue)
                                   for t in self.tenants.values())}, ph="i")

    def unfence(self):
        if self._fenced:
            self._fenced = False
            obs_metrics.counter("engine.unfence").inc()
            if obs_tracing.active():
                obs_tracing.tracer().emit(
                    "engine.unfence", "engine", self.stats.time_s * 1e6,
                    0.0,
                    {"queued": sum(len(t.queue)
                                   for t in self.tenants.values())}, ph="i")

    @property
    def fenced(self) -> bool:
        return self._fenced

    # -- optional real-model backend ------------------------------------
    def attach_model(self, model_cfg, params, max_len: int = 256):
        import jax
        import jax.numpy as jnp

        from repro.models import model as model_lib

        self._model = (model_cfg, params, max_len)
        self._cache = model_lib.init_cache(model_cfg, self.cfg.n_slots, max_len)
        self._tokens = jnp.zeros((self.cfg.n_slots, 1), jnp.int32)
        self._cache_len = 0

        def _step(params, tokens, cache, cache_len):
            # model_lib.decode_step(params, cfg, batch, cache, cache_len)
            return model_lib.decode_step(
                params, model_cfg, {"tokens": tokens}, cache, cache_len
            )

        self._decode = jax.jit(_step)

    def submit(self, req: Request):
        self.tenants[req.tenant].queue.append(req)
        self.stats.sched.account_arrival(req.tenant)
        if self._fenced:
            # arrivals during a fence are deferred, not dropped: they sit
            # in their tenant queue and reconcile once the fence lifts
            self.stats.deferred += 1
            obs_metrics.counter("engine.deferred").inc()

    # -- one engine step --------------------------------------------------
    def step(self):
        cfg = self.cfg
        st = self.stats

        # complete finished requests, free their pages
        still = []
        for r in self.running:
            if r.done:
                r.finish_time = st.time_s
                st.completed.append(r)
                st.sched.account_completion(r.tenant, r.latency)
                self.alloc.free(r.rid)
            else:
                still.append(r)
        self.running = still

        # graceful degradation: return parked requests whose backoff
        # expired, expire requests past their admission deadline, shed
        # overload beyond the queue-depth watermark.  A fenced engine does
        # none of it: parked/queued work is deferred inventory that must
        # survive the fence to reconcile afterwards, and admission is
        # closed anyway.
        if not self._fenced:
            if self._parked:
                self._unpark()
            if cfg.admission_timeout_s > 0:
                self._expire_queued()
            if cfg.shed_watermark > 0:
                self._shed_overload()

        # LAGS global path: lighter waiting tenant may evict a heavy one.
        # Fenced: no preemption (suspending a request would strand it
        # behind the closed admission door) and no admissions — in-flight
        # requests run to completion on the remaining steps.
        running_tids = {r.tenant for r in self.running}
        if not self._fenced:
            preempt, victim = should_preempt(
                cfg.policy, self.tenants, running_tids,
                cfg.preempt_hysteresis
            )
            if preempt and len(self.running) >= cfg.n_slots:
                # suspend one running request of the victim tenant: pages
                # and prefill state are KEPT (the Linux analogue: a
                # preempted thread resumes where it stopped; only the slot
                # is yielded)
                for i, r in enumerate(self.running):
                    if r.tenant == victim:
                        self.tenants[victim].queue.appendleft(r)
                        del self.running[i]
                        break

        # admit into free slots (page-limited)
        free = cfg.n_slots - len(self.running)
        admitted = [] if self._fenced else pick_admissions(
            cfg.policy, self.tenants, free, running_tids
        )
        prefill_toks = 0
        for idx, r in enumerate(admitted):
            if r.rid not in self.alloc.owner:  # resumed requests keep pages
                pages = self.alloc.allocate(r.rid, r.prompt_len + r.max_new)
                if pages is None:
                    # out of pages: park the rejected request with
                    # exponential backoff (the old silent ``appendleft``
                    # made it re-fail at the queue head every step), put
                    # the not-yet-tried admissions back, stop admitting
                    r.rejections += 1
                    r.backoff_until = st.time_s + min(
                        cfg.backoff_base_s * 2.0 ** (r.rejections - 1),
                        cfg.backoff_max_s,
                    )
                    self._parked.append(r)
                    st.backoffs += 1
                    obs_metrics.counter("engine.backoff").inc()
                    for later in reversed(admitted[idx + 1:]):
                        self.tenants[later.tenant].queue.appendleft(later)
                    break
            if r.start_time < 0:
                r.start_time = st.time_s
                # schedstat run delay: queued (runnable) -> first admission
                st.sched.account_run_delay(
                    r.tenant, max(st.time_s - r.arrival, 0.0)
                )
            prefill_toks += 0 if r.prefilled else r.prompt_len
            r.prefilled = True
            self.tenants[r.tenant].last_admit = st.time_s
            self.running.append(r)

        st.sched.sample_runq(
            st.time_s, sum(len(t.queue) for t in self.tenants.values())
        )
        if not self.running:
            st.time_s += cfg.base_step_s  # idle tick
            st.sched.account_time(cfg.base_step_s)
            st.sched.account_idle(cfg.base_step_s)
            st.steps += 1
            if self._fenced:
                st.fenced_steps += 1
                st.sched.account_fenced(cfg.base_step_s)
                obs_metrics.counter("engine.fenced_steps").inc()
            return

        # engine context switch: batch membership changed.  Weight swaps hit
        # only on a residency miss (HBM LRU) — LAGS's run-to-completion
        # clusters a tenant's work in time, raising the hit rate, exactly as
        # same-cgroup switches are cheap in the kernel (§3 / Fig 10).
        members = {r.tenant for r in self.running}
        change = members.symmetric_difference(self._prev_members)
        switch_s = 0.0
        if change:
            swap_mb = 0.0
            swapped: set = set()
            evicted: List[int] = []
            for t in members - self._prev_members:
                if t in self._resident:
                    self._resident.remove(t)  # refresh LRU position
                else:
                    swap_mb += self.tenants[t].weight_mb
                    swapped.add(t)
                self._resident.append(t)
            while len(self._resident) > cfg.max_resident:
                victim_t = next(
                    (x for x in self._resident if x not in members), None
                )
                if victim_t is None:
                    break
                self._resident.remove(victim_t)
                evicted.append(victim_t)
            switch_s = (
                cfg.swap_s_per_mb * swap_mb
                + cfg.dispatch_s_per_member_change * len(change)
            )
            if obs_tracing.active():
                self._trace_residency(swapped, evicted)
            # schedstat switch accounting: one "context switch" per changed
            # member; a residency hit is the cheap same-group analogue
            per_change = switch_s / len(change)
            for t in change:
                st.sched.account_switch(
                    t, per_change, same_group=t not in swapped
                )
            obs_metrics.counter("engine.membership_changes").inc(len(change))
        self._prev_members = members

        # step time: decode for the batch + chunked prefill work
        compute_s = cfg.base_step_s * (len(self.running) / cfg.n_slots) ** 0.5
        compute_s += cfg.per_prefill_tok_s * prefill_toks
        if self._model is not None:
            self._real_decode()

        step_s = compute_s + switch_s
        st.time_s += step_s
        st.sched.account_time(step_s)
        st.steps += 1
        if self._fenced:
            st.fenced_steps += 1
            st.sched.account_fenced(step_s)
            obs_metrics.counter("engine.fenced_steps").inc()
        if obs_tracing.active():
            # trace on the sim clock: one complete event per engine step
            obs_tracing.tracer().emit(
                "engine.step", "engine", (st.time_s - step_s) * 1e6,
                step_s * 1e6,
                {"batch": len(self.running), "switch_ms": switch_s * 1e3,
                 "prefill_toks": prefill_toks},
            )

        # progress: one token per running request
        service_per_req = compute_s / max(len(self.running), 1)
        served: Dict[int, float] = {}
        for r in self.running:
            r.generated += 1
            served[r.tenant] = served.get(r.tenant, 0.0) + service_per_req
        for tid, s in served.items():
            st.sched.account_useful(tid, s)
        if (
            cfg.pallas_threshold
            and len(self.tenants) >= cfg.pallas_threshold
            and pallas_backend.available()
        ):
            self._pallas_tick(served, step_s)
        else:
            for tid, t in self.tenants.items():
                t.tick(served.get(tid, 0.0), step_s, cfg.credit_window)

    # -- graceful degradation ---------------------------------------------
    def _unpark(self):
        """Return parked requests whose backoff expired to the head of
        their tenant queue (they were at the head when rejected); parked
        requests past the admission deadline expire in place."""
        cfg, st = self.cfg, self.stats
        now = st.time_s
        still: List[Request] = []
        for r in self._parked:
            if r.backoff_until > now:
                still.append(r)
            elif (cfg.admission_timeout_s > 0
                  and now - r.arrival > cfg.admission_timeout_s):
                st.expired += 1
                obs_metrics.counter("engine.expired").inc()
            else:
                self.tenants[r.tenant].queue.appendleft(r)
        self._parked = still

    def _expire_queued(self):
        """Drop queued requests whose admission deadline passed.  Requests
        that already ran (preempted, ``start_time >= 0``) are kept — the
        deadline bounds time-to-first-service, not total residence."""
        cfg, st = self.cfg, self.stats
        now = st.time_s
        dropped = 0
        for t in self.tenants.values():
            if not t.queue:
                continue
            keep = [r for r in t.queue
                    if r.start_time >= 0
                    or now - r.arrival <= cfg.admission_timeout_s]
            if len(keep) != len(t.queue):
                dropped += len(t.queue) - len(keep)
                t.queue.clear()
                t.queue.extend(keep)
        if dropped:
            st.expired += dropped
            obs_metrics.counter("engine.expired").inc(dropped)
            if obs_tracing.active():
                obs_tracing.tracer().emit(
                    "engine.expire", "engine", now * 1e6, 0.0,
                    {"dropped": dropped}, ph="i",
                )

    def _shed_overload(self):
        """Past the queue-depth watermark, shed from the highest-credit
        (most-served — the lowest-priority work under LAGS admission
        order) tenants: ``drop`` discards their newest queued requests
        until the depth is back at the watermark; ``truncate`` halves
        ``max_new`` (once per request) on the same number of requests."""
        cfg, st = self.cfg, self.stats
        depth = sum(len(t.queue) for t in self.tenants.values()) \
            + len(self._parked)
        excess = depth - cfg.shed_watermark
        if excess <= 0:
            return
        shed = 0
        order = sorted(self.tenants.values(),
                       key=lambda t: (-t.credit, -t.tid))
        if cfg.shed_mode == "drop":
            for t in order:
                while shed < excess and t.queue:
                    # newest first: requests already waiting keep their turn
                    if t.queue[-1].start_time >= 0:
                        break  # preempted mid-flight work is never shed
                    t.queue.pop()
                    shed += 1
                if shed >= excess:
                    break
        elif cfg.shed_mode == "truncate":
            for t in order:
                for r in t.queue:
                    if shed >= excess:
                        break
                    if not r.truncated and r.generated == 0 and r.max_new > 1:
                        r.max_new = max(1, r.max_new // 2)
                        r.truncated = True
                        shed += 1
                if shed >= excess:
                    break
        else:
            raise ValueError(
                f"unknown shed_mode {cfg.shed_mode!r} (drop|truncate)")
        if shed:
            st.shed += shed
            obs_metrics.counter("engine.shed").inc(shed)
            if obs_tracing.active():
                obs_tracing.tracer().emit(
                    "engine.shed", "engine", st.time_s * 1e6, 0.0,
                    {"mode": cfg.shed_mode, "shed": shed, "depth": depth},
                    ph="i",
                )

    def _pallas_tick(self, served: Dict[int, float], step_s: float):
        """Per-step Load-Credit tick via the fused Pallas kernel.

        One kernel launch replaces the O(T) Python PELT/EMA loop at high
        tenant counts.  Same update rule as ``Tenant.tick`` (f32 on the
        kernel vs f64 in Python — the cross-backend differential tests pin
        the pick order to match within that precision).  The kernel also
        returns the k-lowest-credit pick order — exactly the LAGS admission
        order ``pick_admissions`` applies next step.
        """
        cfg = self.cfg
        tids = sorted(self.tenants)
        load = np.asarray([self.tenants[t].load_avg for t in tids])
        cred = np.asarray([self.tenants[t].credit for t in tids])
        frac = np.asarray(
            [served.get(t, 0.0) / max(step_s, 1e-9) for t in tids]
        )
        runnable = np.asarray(
            [bool(self.tenants[t].queue) for t in tids], bool
        )
        new_load, new_cred, _picks = pallas_backend.tick_and_pick(
            load, cred, frac, runnable, cfg.n_slots,
            window=cfg.credit_window,
        )
        for i, tid in enumerate(tids):
            t = self.tenants[tid]
            t.load_avg = float(new_load[i])
            t.credit = float(new_cred[i])
            t.served_s += served.get(tid, 0.0)

    def _trace_residency(self, swapped: set, evicted: List[int]):
        """Perfetto events for HBM residency churn, on the sim clock:
        one instant per weight swap (tenant + bytes) and a counter track
        sampling HBM occupancy after the LRU update."""
        tr = obs_tracing.tracer()
        now_us = self.stats.time_s * 1e6
        for t in sorted(swapped):
            tr.emit(
                "hbm.swap_in", "residency", now_us, 0.0,
                {"tenant": t, "mb": self.tenants[t].weight_mb}, ph="i",
            )
        for t in evicted:
            tr.emit(
                "hbm.evict", "residency", now_us, 0.0,
                {"tenant": t, "mb": self.tenants[t].weight_mb}, ph="i",
            )
        tr.emit(
            "hbm.resident", "counter", now_us, 0.0,
            {
                "tenants": len(self._resident),
                "mb": sum(self.tenants[x].weight_mb for x in self._resident),
            },
            ph="C",
        )
        obs_metrics.counter("engine.hbm_swaps").inc(len(swapped))
        obs_metrics.counter("engine.hbm_evictions").inc(len(evicted))

    def _real_decode(self):
        import jax.numpy as jnp

        model_cfg, params, max_len = self._model
        if self._cache_len >= max_len - 1:
            return
        logits, self._cache = self._decode(
            params, self._tokens, self._cache, jnp.asarray(self._cache_len)
        )
        self._tokens = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        self._cache_len += 1

    def run(self, until_s: float, arrivals: Optional[List[Request]] = None,
            checkpoint_every_s: float = 0.0, on_checkpoint=None,
            fence_windows: Optional[List] = None):
        """Drive the engine until ``until_s`` sim-seconds, feeding arrivals.

        ``on_checkpoint(stats)`` fires every ``checkpoint_every_s``
        sim-seconds (when both are given) so a live run can stream
        schedstats snapshots — e.g. periodic ``record_run`` checkpoints a
        ``repro.obs.report`` invocation can watch while the run is going.

        ``fence_windows`` is a list of ``(t0, t1)`` sim-second intervals
        during which the engine is fenced (suspected by its controller):
        no admissions, in-flight work completes, arrivals defer — the
        single-engine rehearsal of the fleet controller's SUSPECT tier.
        """
        arrivals = sorted(arrivals or [], key=lambda r: r.arrival)
        windows = sorted(
            (float(a), float(b)) for a, b in (fence_windows or []))
        for a, b in windows:
            if b <= a:
                raise ValueError(f"empty fence window [{a}, {b})")
        ai = 0
        next_ckpt = (
            checkpoint_every_s
            if checkpoint_every_s > 0 and on_checkpoint is not None
            else float("inf")
        )
        while self.stats.time_s < until_s:
            now = self.stats.time_s
            if windows:
                in_fence = any(a <= now < b for a, b in windows)
                if in_fence and not self._fenced:
                    self.fence()
                elif not in_fence and self._fenced:
                    self.unfence()
            while ai < len(arrivals) and arrivals[ai].arrival <= self.stats.time_s:
                self.submit(arrivals[ai])
                ai += 1
            self.step()
            if self.stats.time_s >= next_ckpt:
                on_checkpoint(self.stats)
                while next_ckpt <= self.stats.time_s:
                    next_ckpt += checkpoint_every_s
        if windows and self._fenced:
            self.unfence()
        return self.stats
