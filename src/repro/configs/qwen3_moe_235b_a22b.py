"""qwen3-moe-235b-a22b [moe] — 128 experts top-8, GQA 64/4.

[hf:Qwen/Qwen3-*; hf].  94L d_model=4096 64H (kv=4) expert d_ff=1536
vocab=151936, qk-norm.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        head_dim=128,
        d_ff=0,  # all layers MoE
        vocab_size=151936,
        qk_norm=True,
        n_experts=128,
        top_k=8,
        d_ff_expert=1536,
        moe_period=1,
        rope_theta=1_000_000.0,
    )
)
