"""Model / run configuration for all assigned architectures.

Every architecture in the assigned pool is expressed as a single
``ModelConfig``.  Layer heterogeneity (hybrid attn/mamba interleaves, MoE
periods, local/global sliding-window patterns) is described declaratively and
resolved by :func:`layer_specs` into a per-layer ``LayerSpec`` list; the model
stack groups layers into identical "periods" and scans over them so compile
time is O(period) not O(depth).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer / model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """Resolved structure of one decoder layer."""

    kind: str  # "attn" | "mamba"
    mlp: str  # "dense" | "moe" | "none"
    window: Optional[int]  # sliding-window size; None = global attention


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_kind: str = "default"  # "default" | "mrope" | "none"
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    causal: bool = True
    # sliding-window pattern: every ``global_period``-th layer is global,
    # the rest use ``sliding_window``.  0 = all layers global.
    sliding_window: int = 0
    global_period: int = 0
    # hybrid attn/mamba interleave: layer i is attention iff
    # i % attn_period == attn_offset.  attn_period == 1 -> all attention.
    attn_period: int = 1
    attn_offset: int = 0
    # MoE: layer i is MoE iff moe_period > 0 and i % moe_period == moe_offset
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    moe_period: int = 0
    moe_offset: int = 0
    # Mamba (mamba1)
    ssm_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    # structure
    encoder_only: bool = False
    tie_embeddings: bool = False
    # modality frontend stub: "none" | "vision" | "audio_frames"
    frontend: str = "none"
    n_vision_tokens: int = 1024
    norm_eps: float = 1e-6
    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # ---- derived -----------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dt_rank_resolved(self) -> int:
        return self.dt_rank if self.dt_rank else -(-self.d_model // 16)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_spec(self, i: int) -> LayerSpec:
        if self.attn_period <= 0:
            kind = "mamba"
        elif self.attn_period == 1:
            kind = "attn"
        else:
            kind = "attn" if (i % self.attn_period) == self.attn_offset else "mamba"
        if self.moe_period > 0 and (i % self.moe_period) == self.moe_offset:
            mlp = "moe"
        elif self.d_ff > 0:
            mlp = "dense"
        else:
            mlp = "none"  # pure-SSM archs (falcon-mamba) have no MLP
        window: Optional[int] = None
        if kind == "attn" and self.sliding_window > 0:
            if self.global_period > 0 and (i % self.global_period) == (
                self.global_period - 1
            ):
                window = None  # global layer
            else:
                window = self.sliding_window
        return LayerSpec(kind=kind, mlp=mlp, window=window)


def layer_specs(cfg: ModelConfig):
    return [cfg.layer_spec(i) for i in range(cfg.n_layers)]


def scan_period(cfg: ModelConfig) -> int:
    """Smallest repeating period of layer structure (for scan grouping)."""
    import math

    p = 1
    if cfg.attn_period > 1:
        p = math.lcm(p, cfg.attn_period)
    if cfg.moe_period > 1:
        p = math.lcm(p, cfg.moe_period)
    if cfg.global_period > 1:
        p = math.lcm(p, cfg.global_period)
    return p


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a reason string if this (arch, shape) cell is skipped by rule."""
    if cfg.encoder_only and shape.step == "decode":
        return "encoder-only arch has no decode step"
    if shape.name == "long_500k":
        # needs sub-quadratic attention: run only for SSM / hybrid
        has_full_attn_everywhere = cfg.attn_period == 1 and (
            cfg.sliding_window == 0 or cfg.global_period > 0
        )
        if cfg.family in ("ssm", "hybrid"):
            return None
        if has_full_attn_everywhere or cfg.attn_period == 1:
            return "full-attention arch: long_500k skipped per spec"
    return None


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


_CONFIG_MODULES = [
    "jamba_v0_1_52b",
    "qwen3_8b",
    "stablelm_1_6b",
    "mistral_nemo_12b",
    "gemma3_27b",
    "qwen2_moe_a2_7b",
    "qwen3_moe_235b_a22b",
    "qwen2_vl_7b",
    "falcon_mamba_7b",
    "hubert_xlarge",
]


def _load_all():
    import importlib

    for m in _CONFIG_MODULES:
        importlib.import_module(f"repro.configs.{m}")


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        n_layers=min(cfg.n_layers, 2 * max(scan_period(cfg), 1)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        ssm_state=8,
        dt_rank=8,
        n_vision_tokens=8 if cfg.frontend == "vision" else cfg.n_vision_tokens,
        mrope_sections=(2, 3, 3) if cfg.rope_kind == "mrope" else cfg.mrope_sections,
        sliding_window=16 if cfg.sliding_window else 0,
        dtype="float32",
        param_dtype="float32",
        name=cfg.name + "-reduced",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
