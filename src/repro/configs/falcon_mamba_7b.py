"""falcon-mamba-7b [ssm] — attention-free Mamba1.  [arXiv:2410.05355; unverified].

64L d_model=4096 d_inner=8192 ssm_state=16 vocab=65024; no MLP (the Mamba
block is the whole layer), no positional encoding.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="falcon-mamba-7b",
        family="ssm",
        n_layers=64,
        d_model=4096,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=65024,
        attn_period=0,  # attention-free
        ssm_state=16,
        d_conv=4,
        expand=2,
        rope_kind="none",
        tie_embeddings=True,
    )
)
