"""gemma3-27b [dense] — 5:1 local:global sliding-window pattern, 128k ctx.

[hf:google/gemma-3-*; unverified].  62L d_model=5376 32H (GQA kv=16)
d_ff=21504 vocab=262144; every 6th layer global, others sliding window 1024;
qk-norm.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        qk_norm=True,
        sliding_window=1024,
        global_period=6,
        rope_theta=1_000_000.0,
        tie_embeddings=True,
    )
)
