"""hubert-xlarge [audio] — encoder-only transformer.  [arXiv:2106.07447; unverified].

48L d_model=1280 16H (MHA) d_ff=5120 "vocab"=504 target units.  The conv
waveform feature extractor is a STUB per the assignment: ``input_specs()``
provides precomputed 1280-d frame embeddings.  Training step is masked
prediction over the 504-unit codebook; there is no decode step.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="hubert-xlarge",
        family="audio",
        n_layers=48,
        d_model=1280,
        n_heads=16,
        n_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,
        causal=False,
        encoder_only=True,
        rope_kind="default",  # conv-pos-embedding stubbed; rotary stands in
        frontend="audio_frames",
    )
)
