"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf].  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536 (spec), MoE 16 experts top-2 on every other layer; attention at
layer offset 4 of each 8-layer block (1 attention : 7 mamba).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        attn_period=8,
        attn_offset=4,
        n_experts=16,
        top_k=2,
        d_ff_expert=14336,
        moe_period=2,
        moe_offset=1,
        ssm_state=16,
        d_conv=4,
        expand=2,
        rope_kind="none",  # Jamba uses no positional encoding in attn layers
    )
)
