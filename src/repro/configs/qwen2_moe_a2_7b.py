"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].  24L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=151936; shared expert = 4 x 1408 = 5632.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-moe-a2.7b",
        family="moe",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        head_dim=128,
        d_ff=0,  # all layers MoE
        vocab_size=151936,
        n_experts=60,
        top_k=4,
        d_ff_expert=1408,
        n_shared_experts=4,
        moe_period=1,
        rope_theta=1_000_000.0,
    )
)
