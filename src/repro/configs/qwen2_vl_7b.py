"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution.  [arXiv:2409.12191; hf].

Backbone only: 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings which replace the first ``n_vision_tokens``
positions; M-RoPE 3-section (temporal/height/width) rotary is implemented on
the backbone with position ids supplied as input.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        head_dim=128,
        d_ff=18944,
        vocab_size=152064,
        rope_kind="mrope",
        mrope_sections=(16, 24, 24),
        frontend="vision",
        n_vision_tokens=1024,
        rope_theta=1_000_000.0,
    )
)
