"""Mamba-1 selective SSM block (falcon-mamba / jamba mixer).

Training/prefill uses a chunked scan: ``lax.scan`` over fixed-size time
chunks carrying the SSM state, ``lax.associative_scan`` (log-depth) within a
chunk — bounding live memory to O(B * chunk * d_inner * d_state) while
keeping compile time independent of sequence length.  Decode is a single
recurrence step on cached (h, conv) state.  The TPU kernel path is
``repro.kernels.ssm_scan``.

The channel dimension (d_inner) is sharded over "model": conv, gating and the
scan are element-wise over channels, so TP needs no collectives outside the
in/out projections.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec


def mamba_specs(cfg: ModelConfig) -> dict:
    M, I, N, R, W = (
        cfg.d_model,
        cfg.d_inner,
        cfg.ssm_state,
        cfg.dt_rank_resolved,
        cfg.d_conv,
    )
    pd = cfg.param_dtype
    return {
        "in_proj": ParamSpec((M, 2 * I), pd, ("embed_p", "ssm_inner")),
        "conv_w": ParamSpec((W, I), pd, ("conv", "ssm_inner")),
        "conv_b": ParamSpec((I,), pd, ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((I, R + 2 * N), pd, ("ssm_inner", None)),
        "dt_proj": ParamSpec((R, I), pd, ("dt_rank", "ssm_inner")),
        "dt_bias": ParamSpec((I,), "float32", ("ssm_inner",), init="zeros"),
        "A_log": ParamSpec((I, N), "float32", ("ssm_inner", "ssm_state"), init="ssm_a"),
        "D": ParamSpec((I,), "float32", ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((I, M), pd, ("ssm_inner", "embed_p")),
    }


def _conv_shift(x_pad, w, b, S: int):
    """Causal depthwise conv via shifted adds.  x_pad: (B, S+W-1, I)."""
    W = w.shape[0]
    y = None
    for j in range(W):
        term = x_pad[:, j : j + S, :] * w[j]
        y = term if y is None else y + term
    return y + b


def _ssm_chunk(dA, dBx, h0):
    """Within-chunk scan.  dA/dBx: (B, cs, I, N); h0: (B, I, N).

    Sequential ``lax.scan`` over time: the log-depth associative scan costs
    O(cs * log cs) live (B, cs, I, N) temporaries in the backward pass,
    which blows past HBM for d_inner=8192 stacks (jamba/falcon train); the
    sequential form saves one (B, I, N) carry per step and the chunking
    bounds the recompute window.  On TPU the fused time loop is
    ``repro.kernels.ssm_scan``.
    """

    def step(h, xs):
        a, b = xs
        h = a * h + b
        return h, h

    h_last, hs = jax.lax.scan(
        step, h0, (dA.transpose(1, 0, 2, 3), dBx.transpose(1, 0, 2, 3))
    )
    return hs.transpose(1, 0, 2, 3), h_last


def mamba_mixer(
    params: dict,
    x,
    cfg: ModelConfig,
    cache: dict | None = None,
    chunk: int = 256,
):
    """x: (B, S, M) -> (y, new_cache).  cache = {"h": (B,I,N) f32, "conv": (B,W-1,I)}."""
    B, S, M = x.shape
    I, N, R, W = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_resolved, cfg.d_conv
    dt_ = x.dtype

    xz = jnp.einsum("bsm,mi->bsi", x, params["in_proj"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, "batch", "seq", "ssm_inner")

    conv_init = (
        cache["conv"].astype(dt_)
        if cache is not None
        else jnp.zeros((B, W - 1, I), dt_)
    )
    x_pad = jnp.concatenate([conv_init, xin], axis=1)
    new_conv = x_pad[:, -(W - 1) :, :]
    xc = jax.nn.silu(_conv_shift(x_pad, params["conv_w"].astype(dt_),
                                 params["conv_b"].astype(dt_), S))

    xdb = jnp.einsum("bsi,ir->bsr", xc, params["x_proj"].astype(dt_))
    dt_raw, Bm, Cm = jnp.split(xdb, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_raw, params["dt_proj"].astype(dt_)).astype(
            jnp.float32
        )
        + params["dt_bias"]
    )  # (B,S,I) fp32
    A = -jnp.exp(params["A_log"])  # (I,N) fp32
    Bm32, Cm32, xc32 = (
        Bm.astype(jnp.float32),
        Cm.astype(jnp.float32),
        xc.astype(jnp.float32),
    )

    h0 = (
        cache["h"].astype(jnp.float32)
        if cache is not None
        else jnp.zeros((B, I, N), jnp.float32)
    )

    if S == 1:  # decode: single recurrence step
        dA = jnp.exp(dt[:, 0, :, None] * A)  # (B,I,N)
        dBx = dt[:, 0, :, None] * Bm32[:, 0, None, :] * xc32[:, 0, :, None]
        h = dA * h0 + dBx
        y = jnp.einsum("bin,bn->bi", h, Cm32[:, 0])[:, None, :]  # (B,1,I)
        h_last = h
    elif S <= chunk:
        dA = jnp.exp(dt[..., None] * A)  # (B,S,I,N)
        dBx = dt[..., None] * Bm32[:, :, None, :] * xc32[..., None]
        hs, h_last = _ssm_chunk(dA, dBx, h0)
        y = jnp.einsum("bsin,bsn->bsi", hs, Cm32)
    else:
        assert S % chunk == 0, (S, chunk)
        n = S // chunk

        def body(h_carry, xs):
            dt_c, B_c, C_c, x_c = xs  # (B,chunk,...)
            dA = jnp.exp(dt_c[..., None] * A)
            dBx = dt_c[..., None] * B_c[:, :, None, :] * x_c[..., None]
            hs, h_out = _ssm_chunk(dA, dBx, h_carry)
            y_c = jnp.einsum("bsin,bsn->bsi", hs, C_c)
            return h_out, y_c

        resh = lambda a: a.reshape(B, n, chunk, *a.shape[2:]).transpose(
            1, 0, 2, *range(3, a.ndim + 1)
        )
        h_last, ys = jax.lax.scan(body, h0, (resh(dt), resh(Bm32), resh(Cm32), resh(xc32)))
        y = ys.transpose(1, 0, 2, 3).reshape(B, S, I)

    y = (y + xc32 * params["D"]).astype(dt_) * jax.nn.silu(z)
    out = jnp.einsum("bsi,im->bsm", y, params["out_proj"].astype(dt_))
    new_cache = {"h": h_last, "conv": new_conv} if cache is not None else None
    return constrain(out, "batch", "seq", None), new_cache
