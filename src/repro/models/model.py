"""Top-level model: embeddings, stack, chunked LM loss, prefill/decode.

Public API (all pure functions over param pytrees):
  abstract_params(cfg)            -> ParamSpec tree (no allocation)
  init_params(cfg, rng)           -> array tree
  cache_specs(cfg, batch, max_len)-> ParamSpec tree for the KV/SSM cache
  train_loss(params, cfg, batch)  -> (loss, metrics)
  prefill(params, cfg, batch)     -> (last_logits, cache)
  decode_step(params, cfg, batch, cache, cache_len) -> (logits, cache)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import blocks
from repro.models.common import rms_norm, softmax_cross_entropy
from repro.models.params import ParamSpec, materialize, spec_to_sds


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig) -> dict:
    V, M = cfg.vocab_size, cfg.d_model
    pd = cfg.param_dtype
    p = {
        "embed": ParamSpec((V, M), pd, ("vocab", "embed_p"), init="embed"),
        "stack": blocks.stack_param_specs(cfg),
        "final_norm": ParamSpec((M,), "float32", (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = ParamSpec((M, V), pd, ("embed_p", "vocab"))
    return p


def init_params(cfg: ModelConfig, rng) -> dict:
    return materialize(abstract_params(cfg), rng)


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    return blocks.stack_cache_specs(cfg, batch, max_len)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.jnp_dtype),
        cache_specs(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, ParamSpec),
    )


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def _embed_tokens(params, cfg: ModelConfig, batch: dict):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio_frames":
        x = batch["frames"].astype(dt)  # stub frontend: precomputed embeddings
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(dt)
        if cfg.frontend == "vision" and "vision_embeds" in batch:
            v = batch["vision_embeds"].astype(dt)
            x = jax.lax.dynamic_update_slice(x, v, (0, 0, 0))
    return constrain(x, "batch", "seq", None)


def _positions(cfg: ModelConfig, batch: dict, B: int, S: int, cache_len=None):
    if "positions" in batch:
        return batch["positions"]
    if cache_len is not None:
        pos = jnp.full((B, S), cache_len, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if cfg.rope_kind == "mrope":
        pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return pos


def unembed_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T  # (M, V)
    return params["unembed"]


# ---------------------------------------------------------------------------
# Chunked LM loss: never materialises (B, S, V) logits
# ---------------------------------------------------------------------------


def chunked_lm_loss(params, cfg: ModelConfig, x, targets, mask, chunk: int = 512):
    B, S, M = x.shape
    w = unembed_matrix(params, cfg).astype(x.dtype)
    if S <= chunk:
        logits = jnp.einsum("bsm,mv->bsv", x, w)
        logits = constrain(logits, "batch", "seq", "vocab")
        nll = softmax_cross_entropy(logits, targets, mask)
        return nll
    n = S // chunk
    assert S % chunk == 0

    # checkpoint: recompute the (B, chunk, V) logits in the backward pass
    # instead of saving every chunk's logits (V is huge)
    @jax.checkpoint
    def body(carry, xs):
        xc, tc, mc = xs  # (B, chunk, ...)
        logits = jnp.einsum("bsm,mv->bsv", xc, w)
        logits = constrain(logits, "batch", "seq", "vocab").astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        mcf = mc.astype(jnp.float32)
        return (carry[0] + jnp.sum((lse - ll) * mcf), carry[1] + jnp.sum(mcf)), ()

    resh = lambda a: a.reshape(B, n, chunk, *a.shape[2:]).transpose(
        (1, 0, 2) + tuple(range(3, a.ndim + 1))
    )
    (tot, cnt), _ = jax.lax.scan(
        body,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (resh(x), resh(targets), resh(mask)),
    )
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def train_loss(params, cfg: ModelConfig, batch: dict, remat: bool = True):
    """Causal-LM (or masked-prediction for encoder archs) training loss."""
    if cfg.frontend == "audio_frames":
        B, S = batch["frames"].shape[:2]
    else:
        B, S = batch["tokens"].shape
    x = _embed_tokens(params, cfg, batch)
    pos = _positions(cfg, batch, B, S)
    x, _, aux = blocks.apply_stack(cfg, params["stack"], x, pos, remat=remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    x = constrain(x, "batch", "seq_sp", None)
    mask = batch.get("loss_mask", jnp.ones((B, S), jnp.float32))
    nll = chunked_lm_loss(params, cfg, x, batch["targets"], mask)
    loss = nll + 0.01 * aux
    return loss, {"nll": nll, "aux": aux}


def prefill(params, cfg: ModelConfig, batch: dict, max_len: int | None = None):
    """Forward over a prompt, filling the cache.  Returns (last_logits, cache)."""
    if cfg.frontend == "audio_frames":
        B, S = batch["frames"].shape[:2]
    else:
        B, S = batch["tokens"].shape
    max_len = max_len or S
    x = _embed_tokens(params, cfg, batch)
    pos = _positions(cfg, batch, B, S)
    if cfg.encoder_only:
        x, _, _ = blocks.apply_stack(cfg, params["stack"], x, pos)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = jnp.einsum(
            "bsm,mv->bsv", x, unembed_matrix(params, cfg).astype(x.dtype)
        )
        return logits[:, -1], None
    cache = init_cache(cfg, B, max_len)
    x, cache, _ = blocks.apply_stack(
        cfg, params["stack"], x, pos, cache=cache, cache_len=jnp.zeros((), jnp.int32)
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    last = x[:, -1:, :]
    logits = jnp.einsum(
        "bsm,mv->bsv", last, unembed_matrix(params, cfg).astype(x.dtype)
    )
    return logits[:, 0], cache


def decode_step(params, cfg: ModelConfig, batch: dict, cache, cache_len):
    """One incremental token.  batch["tokens"]: (B, 1).  Returns (logits, cache)."""
    B, S = batch["tokens"].shape
    x = _embed_tokens(params, cfg, batch)
    pos = _positions(cfg, batch, B, S, cache_len=cache_len)
    x, cache, _ = blocks.apply_stack(
        cfg, params["stack"], x, pos, cache=cache, cache_len=cache_len
    )
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum(
        "bsm,mv->bsv", x, unembed_matrix(params, cfg).astype(x.dtype)
    )
    return logits[:, 0], cache
