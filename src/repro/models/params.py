"""Parameter metadata trees.

``abstract_params`` builds a pytree of :class:`ParamSpec` (shape, dtype,
logical axes) with **no allocation** — the dry-run lowers directly from these.
``materialize`` turns the same tree into real arrays with path-keyed RNG.
"""
from __future__ import annotations

import hashlib
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import to_pspec


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: str
    logical: Tuple  # logical axis names, len == len(shape)
    init: str = "dense"  # "dense" | "embed" | "zeros" | "ones" | "ssm_a"

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)


def is_spec(x):
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def spec_to_sds(tree):
    """ParamSpec tree -> jax.ShapeDtypeStruct tree (for .lower())."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.jnp_dtype), tree
    )


def spec_to_pspecs(tree, rules=None, mesh=None):
    """ParamSpec tree -> PartitionSpec tree (for in_shardings)."""
    return tree_map_specs(
        lambda s: to_pspec(s.logical, rules=rules, mesh=mesh, shape=s.shape),
        tree,
    )


def constrain_like(tree, spec_tree):
    """Apply with_sharding_constraint to every leaf per its ParamSpec logical
    axes (no-op without an active sharding context).  Used to force XLA to
    keep gradients / optimizer updates in the parameters' sharded layout
    instead of falling back to replicated math."""
    import jax as _jax
    from repro.distributed.sharding import active_mesh, constrain

    if active_mesh() is None:
        return tree

    def one(leaf, spec):
        return constrain(leaf, *spec.logical)

    return _jax.tree_util.tree_map(
        lambda s, l: one(l, s), spec_tree, tree, is_leaf=is_spec
    )


def _path_key(root_key, path) -> jax.Array:
    h = hashlib.md5("/".join(str(p) for p in path).encode()).digest()
    return jax.random.fold_in(root_key, int.from_bytes(h[:4], "little"))


def materialize(tree, root_key):
    """Instantiate a ParamSpec tree into real arrays (smoke tests, examples)."""

    def init_one(path, spec: ParamSpec):
        key = _path_key(root_key, [getattr(p, "key", getattr(p, "idx", p)) for p in path])
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, spec.jnp_dtype)
        if spec.init == "ones":
            return jnp.ones(spec.shape, spec.jnp_dtype)
        if spec.init == "embed":
            x = jax.random.normal(key, spec.shape, jnp.float32) * 0.02
        elif spec.init == "ssm_a":
            # mamba A_log init: log(1..d_state) broadcast over channels
            n = spec.shape[-1]
            a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
            x = jnp.broadcast_to(a, spec.shape)
        else:
            fan_in = spec.shape[0] if len(spec.shape) > 1 else spec.shape[-1]
            x = jax.random.normal(key, spec.shape, jnp.float32) / jnp.sqrt(
                float(max(fan_in, 1))
            )
        return x.astype(spec.jnp_dtype)

    return jax.tree_util.tree_map_with_path(init_one, tree, is_leaf=is_spec)


def count_params(tree) -> int:
    import numpy as np

    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_spec):
        shape = leaf.shape if is_spec(leaf) else leaf.shape
        total += int(np.prod(shape)) if len(shape) else 1
    return total
