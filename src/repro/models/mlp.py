"""Dense SwiGLU MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    M = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    pd = cfg.param_dtype
    return {
        "w_gate": ParamSpec((M, F), pd, ("embed_p", "mlp")),
        "w_up": ParamSpec((M, F), pd, ("embed_p", "mlp")),
        "w_down": ParamSpec((F, M), pd, ("mlp", "embed_p")),
    }


def mlp(params: dict, x):
    g = jnp.einsum("bsm,mf->bsf", x, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsm,mf->bsf", x, params["w_up"].astype(x.dtype))
    h = constrain(jax.nn.silu(g) * u, "batch", "seq", "mlp")
    y = jnp.einsum("bsf,fm->bsm", h, params["w_down"].astype(x.dtype))
    # reduce-scatter into the sequence-sharded residual (Megatron-SP)
    return constrain(y, "batch", "seq_sp", None)
