"""Decoder layers + period-grouped scan over depth.

Layer heterogeneity (jamba's 1:7 attn:mamba interleave, gemma3's 5:1
local:global windows, MoE periods) repeats with a fixed period P; we stack
parameters per period-position over ``n_rep = n_layers // P`` repetitions and
``lax.scan`` over repetitions, applying the P distinct layer bodies in order.
Compile time is O(P), not O(n_layers).  Layers beyond ``n_rep * P`` (gemma3's
remainder 2) are unrolled with their own parameters.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, layer_specs, scan_period
from repro.distributed.sharding import constrain
from repro.models import attention, mamba, mlp, moe
from repro.models.common import rms_norm
from repro.models.params import ParamSpec, is_spec


# ---------------------------------------------------------------------------
# Single layer
# ---------------------------------------------------------------------------


def layer_param_specs(cfg: ModelConfig, spec: LayerSpec) -> dict:
    M, pd = cfg.d_model, cfg.param_dtype
    p: dict = {"ln1": ParamSpec((M,), "float32", (None,), init="ones")}
    if spec.kind == "attn":
        p["attn"] = attention.attn_specs(cfg)
    else:
        p["mamba"] = mamba.mamba_specs(cfg)
    if spec.mlp == "dense":
        p["ln2"] = ParamSpec((M,), "float32", (None,), init="ones")
        p["mlp"] = mlp.mlp_specs(cfg)
    elif spec.mlp == "moe":
        p["ln2"] = ParamSpec((M,), "float32", (None,), init="ones")
        p["moe"] = moe.moe_specs(cfg)
    return p


def layer_cache_specs(
    cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int
) -> Optional[dict]:
    if spec.kind == "attn":
        kv = ParamSpec(
            (batch, max_len, cfg.n_kv_heads, cfg.head_dim),
            cfg.dtype,
            ("batch", "kv_seq", "kv_heads", None),
            init="zeros",
        )
        return {"k": kv, "v": kv}
    return {
        "h": ParamSpec(
            (batch, cfg.d_inner, cfg.ssm_state),
            "float32",
            ("batch", "ssm_inner", "ssm_state"),
            init="zeros",
        ),
        "conv": ParamSpec(
            (batch, cfg.d_conv - 1, cfg.d_inner),
            cfg.dtype,
            ("batch", None, "ssm_inner"),
            init="zeros",
        ),
    }


def apply_layer(
    cfg: ModelConfig,
    spec: LayerSpec,
    params: dict,
    x,
    positions,
    cache: Optional[dict] = None,
    cache_len=None,
):
    """Pre-norm residual layer.  Returns (x, new_cache, aux_loss)."""
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if spec.kind == "attn":
        y, new_cache = attention.multihead_attention(
            params["attn"], h, cfg, positions,
            window=spec.window, cache=cache, cache_len=cache_len,
        )
    else:
        y, new_cache = mamba.mamba_mixer(params["mamba"], h, cfg, cache=cache)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if spec.mlp == "dense":
        x = x + mlp.mlp(params["mlp"], rms_norm(x, params["ln2"], cfg.norm_eps))
    elif spec.mlp == "moe":
        y2, aux = moe.moe(params["moe"], rms_norm(x, params["ln2"], cfg.norm_eps), cfg)
        x = x + y2
    return constrain(x, "batch", "seq_sp", None), new_cache, aux


# ---------------------------------------------------------------------------
# Stack: period-grouped scan
# ---------------------------------------------------------------------------


def stack_layout(cfg: ModelConfig):
    """Returns (period P, n_rep, remainder layer indices)."""
    P = scan_period(cfg)
    n_rep = cfg.n_layers // P
    rem = cfg.n_layers - n_rep * P
    return P, n_rep, rem


def _stack_specs(tree, n: int):
    return jax.tree_util.tree_map(
        lambda s: ParamSpec((n,) + s.shape, s.dtype, (None,) + s.logical, s.init),
        tree,
        is_leaf=is_spec,
    )


def stack_param_specs(cfg: ModelConfig) -> dict:
    P, n_rep, rem = stack_layout(cfg)
    specs = layer_specs(cfg)
    body = [
        _stack_specs(layer_param_specs(cfg, specs[i]), n_rep) for i in range(P)
    ]
    remainder = [
        layer_param_specs(cfg, specs[n_rep * P + j]) for j in range(rem)
    ]
    return {"body": body, "rem": remainder}


def stack_cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    P, n_rep, rem = stack_layout(cfg)
    specs = layer_specs(cfg)
    body = [
        _stack_specs(layer_cache_specs(cfg, specs[i], batch, max_len), n_rep)
        for i in range(P)
    ]
    remainder = [
        layer_cache_specs(cfg, specs[n_rep * P + j], batch, max_len)
        for j in range(rem)
    ]
    return {"body": body, "rem": remainder}


def apply_stack(
    cfg: ModelConfig,
    params: dict,
    x,
    positions,
    cache=None,
    cache_len=None,
    remat: bool = False,
):
    """Returns (x, new_cache, aux_sum)."""
    P, n_rep, rem = stack_layout(cfg)
    specs = layer_specs(cfg)
    have_cache = cache is not None

    def one_layer(pos, xc, p_params, c):
        return apply_layer(
            cfg, specs[pos], p_params, xc, positions, c, cache_len
        )

    if remat:
        # per-layer remat *inside* the period: the period backward otherwise
        # holds all P layers' recomputed intermediates simultaneously
        one_layer = jax.checkpoint(
            one_layer,
            policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(0,),
        )

    def period_body(carry, xs):
        xc, aux = carry
        p_params, p_cache = xs
        new_caches = []
        for pos in range(P):
            c = p_cache[pos] if have_cache else None
            xc, nc, a = one_layer(pos, xc, p_params[pos], c)
            new_caches.append(nc if have_cache else jnp.zeros((), x.dtype))
            aux = aux + a
        return (xc, aux), new_caches

    body = period_body
    if remat:
        body = jax.checkpoint(
            period_body, policy=jax.checkpoint_policies.nothing_saveable
        )

    xs_cache = cache["body"] if have_cache else [jnp.zeros((n_rep,), x.dtype)] * P
    (x, aux), new_body_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (params["body"], xs_cache)
    )

    new_rem_cache = []
    for j in range(rem):
        i = n_rep * P + j
        c = cache["rem"][j] if have_cache else None
        x, nc, a = apply_layer(cfg, specs[i], params["rem"][j], x, positions, c, cache_len)
        new_rem_cache.append(nc)
        aux = aux + a

    new_cache = (
        {"body": new_body_cache, "rem": new_rem_cache} if have_cache else None
    )
    return x, new_cache, aux
