"""GQA attention with chunked online-softmax, sliding windows and KV cache.

The pure-XLA path below is the dry-run / CPU reference; on TPU the same
contraction is served by ``repro.kernels.flash_attention`` (prefill) and
``repro.kernels.decode_attention`` (decode) — selected via ``use_pallas``.
Queries are processed in chunks under ``lax.scan`` so the score matrix never
materialises beyond (B, Hkv, G, chunk, Skv), bounding live memory the same
way a flash kernel bounds VMEM.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import active_mesh, constrain
from repro.models.common import apply_rope, rms_norm
from repro.models.params import ParamSpec

NEG_INF = -1e30


def _tp_size() -> int:
    mesh = active_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape))["model"]


def attn_specs(cfg: ModelConfig) -> dict:
    M, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pd = cfg.param_dtype
    specs = {
        "wq": ParamSpec((M, H, D), pd, ("embed_p", "heads", None)),
        "wk": ParamSpec((M, Hkv, D), pd, ("embed_p", "kv_heads", None)),
        "wv": ParamSpec((M, Hkv, D), pd, ("embed_p", "kv_heads", None)),
        "wo": ParamSpec((H, D, M), pd, ("heads", None, "embed_p")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((D,), "float32", (None,), init="ones")
        specs["k_norm"] = ParamSpec((D,), "float32", (None,), init="ones")
    return specs


def _attend_chunk(q, k, v, q_pos, k_pos, kv_len, causal, window,
                  kv_sharded=False):  # noqa: D401
    """q: (B,Cq,Hkv,G,D) k/v: (B,Skv,Hkv,D) -> (B,Cq,Hkv,G,D)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = jnp.einsum(
        "bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    mask = k_pos[:, None, :] < kv_len[:, :, None]  # (B,1,Skv) valid entries
    if causal:
        mask = mask & (k_pos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        mask = mask & (k_pos[:, None, :] > q_pos[:, :, None] - window)
    mask = mask[:, None, None, :, :]  # (B,1,1,Cq,Skv)
    scores = jnp.where(mask, scores, NEG_INF)
    if kv_sharded:
        # long-KV decode: keep scores sharded over the KV shards so the
        # softmax runs distributed (flash-decode) instead of gathering the
        # cache.  Never applied on the train path (it would force score
        # replication over "model" — EXPERIMENTS.md §Perf H2/H4 post-mortem).
        scores = constrain(scores, "batch", None, None, None, "kv_seq")
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - jax.lax.stop_gradient(jnp.maximum(m, NEG_INF / 2)))
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.maximum(denom, 1e-30)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)


def multihead_attention(
    params: dict,
    x,
    cfg: ModelConfig,
    positions,
    window: Optional[int] = None,
    cache: Optional[dict] = None,
    cache_len=None,
    q_chunk: int = 1024,
):
    """Returns (y, new_cache).  ``cache`` is {"k","v"} of (B, L, Hkv, D)."""
    B, S, M = x.shape
    H, Hkv, D, G = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.q_per_kv

    q = jnp.einsum("bsm,mhd->bshd", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsm,mhd->bshd", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsm,mhd->bshd", x, params["wv"].astype(x.dtype))
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)

    # token positions for rope: (B,S) or (B,S,3) for M-RoPE
    if cfg.rope_kind == "mrope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        pos_1d = positions[..., 0]
    elif cfg.rope_kind == "default":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        pos_1d = positions
    else:
        pos_1d = positions if positions.ndim == 2 else positions[..., 0]

    new_cache = None
    if cache is not None:
        # decode / incremental: write new k,v at cache_len, attend over cache
        ck, cv = cache["k"], cache["v"]
        Lmax = ck.shape[1]
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache_len, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache_len, 0, 0))
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", None)
        new_cache = {"k": ck, "v": cv}
        k_att, v_att = ck.astype(x.dtype), cv.astype(x.dtype)
        k_pos = jnp.broadcast_to(jnp.arange(Lmax, dtype=jnp.int32), (B, Lmax))
        kv_len = jnp.full((B, 1), cache_len + S, jnp.int32)
    else:
        k_att, v_att = k, v
        k_pos = pos_1d.astype(jnp.int32)
        kv_len = jnp.max(k_pos, axis=-1, keepdims=True) + 1  # all keys valid

    q_pos = pos_1d.astype(jnp.int32)

    if S > 1 and G > 1:
        # prefill/train: repeat KV to full head count so the contraction
        # stays sharded on a mesh-divisible "heads" axis (XLA fuses the
        # broadcast; no materialised 4x KV).  Decode keeps the grouped form:
        # the cache is KV-sequence-sharded and heads are replicated.
        k_att = jnp.repeat(k_att, G, axis=2)
        v_att = jnp.repeat(v_att, G, axis=2)
        qg = q.reshape(B, S, H, 1, D)
        Hg, Gg = H, 1
        tp = _tp_size()
        if Hg % tp:
            # pad heads to a mesh-divisible count (qwen2-vl: 28 -> 32) so
            # the score tensor shards over "model" instead of replicating
            hp = -(-Hg // tp) * tp
            qg = jnp.pad(qg, [(0, 0), (0, 0), (0, hp - Hg), (0, 0), (0, 0)])
            k_att = jnp.pad(k_att, [(0, 0), (0, 0), (0, hp - Hg), (0, 0)])
            v_att = jnp.pad(v_att, [(0, 0), (0, 0), (0, hp - Hg), (0, 0)])
            Hg = hp
        qg = constrain(qg, "batch", "seq", "heads", None, None)
        k_att = constrain(k_att, "batch", "seq", "heads", None)
        v_att = constrain(v_att, "batch", "seq", "heads", None)
    else:
        qg = q.reshape(B, S, Hkv, G, D)
        Hg, Gg = Hkv, G

    decode_mode = cache is not None and S == 1
    if S <= q_chunk:
        out = _attend_chunk(qg, k_att, v_att, q_pos, k_pos, kv_len,
                            cfg.causal, window, kv_sharded=decode_mode)
    else:
        assert S % q_chunk == 0, (S, q_chunk)
        n = S // q_chunk
        qs = qg.reshape(B, n, q_chunk, Hg, Gg, D).transpose(1, 0, 2, 3, 4, 5)
        ps = q_pos.reshape(B, n, q_chunk).transpose(1, 0, 2)

        # checkpoint: recompute per-chunk attention probabilities in the
        # backward pass (flash-attention-style) instead of saving them
        @jax.checkpoint
        def body(_, qp):
            qc, pc = qp
            oc = _attend_chunk(qc, k_att, v_att, pc, k_pos, kv_len, cfg.causal, window)
            return (), oc

        _, outs = jax.lax.scan(body, (), (qs, ps))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, Hg, Gg, D)

    if Hg * Gg != H:  # slice off padded heads
        out = out.reshape(B, S, Hg * Gg, D)[:, :, :H, :]
    out = out.reshape(B, S, H, D)
    y = jnp.einsum("bshd,hdm->bsm", out, params["wo"].astype(x.dtype))
    # reduce-scatter the TP-partial output into the sequence-sharded residual
    # (Megatron-SP output half; halves wire vs an all-reduce to full seq)
    return constrain(y, "batch", "seq_sp", None), new_cache
