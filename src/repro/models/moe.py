"""Mixture-of-Experts layer.

Baseline path: GShard-style grouped capacity dispatch expressed as einsums —
predictably shardable under GSPMD (groups -> "batch" axes, experts ->
"model").  The dispatch/combine one-hot einsums cost ~2*T*M*E*C extra FLOPs;
EXPERIMENTS.md §Perf swaps in the sort-based EP all-to-all path
(``repro.distributed.ep_a2a``) which removes them.

Routing: softmax router, top-k, Switch-style load-balancing aux loss.
Tokens beyond expert capacity are dropped (contribute zero) — standard
capacity-factor semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.params import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    M, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    pd = cfg.param_dtype
    specs = {
        "w_router": ParamSpec((M, E), "float32", ("embed_p", None)),
        "w_gate": ParamSpec((E, M, F), pd, ("experts", "embed_p", "expert_mlp")),
        "w_up": ParamSpec((E, M, F), pd, ("experts", "embed_p", "expert_mlp")),
        "w_down": ParamSpec((E, F, M), pd, ("experts", "expert_mlp", "embed_p")),
    }
    if cfg.n_shared_experts:
        Fs = cfg.n_shared_experts * cfg.d_ff_expert
        specs["shared"] = {
            "w_gate": ParamSpec((M, Fs), pd, ("embed_p", "mlp")),
            "w_up": ParamSpec((M, Fs), pd, ("embed_p", "mlp")),
            "w_down": ParamSpec((Fs, M), pd, ("mlp", "embed_p")),
        }
    return specs


def _capacity(gs: int, k: int, e: int, factor: float = 1.25) -> int:
    c = int(-(-gs * k * factor // e))
    return max(4, -(-c // 4) * 4) if gs > 1 else max(1, c)


def moe(params: dict, x, cfg: ModelConfig, group_size: int = 256):
    """x: (B, S, M) -> (y, aux_loss)."""
    B, S, M = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dt = x.dtype

    if S == 1:  # decode: one token per group, capacity 1 slot per expert
        gs = 1
    else:
        gs = min(group_size, S)
    gr = (B * S) // gs
    C = _capacity(gs, K, E)
    xg = x.reshape(gr, gs, M)
    xg = constrain(xg, "batch", None, None)

    # --- routing (fp32 softmax; bf16 dot so cotangents stay bf16 — a f32
    # router dot leaks f32 into every MoE gradient collective, §Perf H7) ---
    logits = jnp.einsum(
        "gsm,me->gse", xg, params["w_router"].astype(dt),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_w, ids = jax.lax.top_k(probs, K)  # (gr, gs, K)
    gate_w = gate_w / jnp.maximum(jnp.sum(gate_w, -1, keepdims=True), 1e-9)

    # Switch aux loss: E * mean_e(frac_tokens_e * mean_prob_e)
    me = jnp.mean(probs, axis=(0, 1))  # (E,)
    onehot_top1 = jax.nn.one_hot(ids[..., 0], E, dtype=jnp.float32)
    ce = jnp.mean(onehot_top1, axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # --- position of each (token, k) within its expert (per group) ---
    oh = jax.nn.one_hot(ids.reshape(gr, gs * K), E, dtype=jnp.int32)  # (gr,T,E)
    pos = jnp.cumsum(oh, axis=1) - 1  # (gr, T, E)
    pos_k = jnp.take_along_axis(
        pos, ids.reshape(gr, gs * K)[..., None], axis=-1
    )[..., 0].reshape(gr, gs, K)
    keep = (pos_k < C).astype(jnp.float32) * (gate_w > 0)

    # combine tensor (gr, gs, E, C): sum_k gate_w_k * onehot(e_k) x onehot(c_k)
    eh = jax.nn.one_hot(ids, E, dtype=dt)  # (gr, gs, K, E)
    ch = jax.nn.one_hot(jnp.clip(pos_k, 0, C - 1), C, dtype=dt)  # (gr, gs, K, C)
    combine = jnp.einsum(
        "gske,gskc->gsec", eh * (gate_w * keep).astype(dt)[..., None], ch
    )
    dispatch = (combine > 0).astype(dt)
    combine = constrain(combine, "batch", None, "experts", None)
    dispatch = constrain(dispatch, "batch", None, "experts", None)

    # --- dispatch -> expert FFN -> combine ---
    expert_in = jnp.einsum("gsec,gsm->egcm", dispatch, xg)
    expert_in = constrain(expert_in, "experts", "batch", None, None)
    g = jnp.einsum("egcm,emf->egcf", expert_in, params["w_gate"].astype(dt))
    u = jnp.einsum("egcm,emf->egcf", expert_in, params["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("egcf,efm->egcm", h, params["w_down"].astype(dt))
    eo = constrain(eo, "experts", "batch", None, None)
    y = jnp.einsum("gsec,egcm->gsm", combine, eo)
    # reduce-scatter the expert-partial output into the seq-sharded residual
    y = constrain(y.reshape(B, S, M), "batch", "seq_sp", None)

    if "shared" in params:
        from repro.models.mlp import mlp as dense_mlp

        y = y + dense_mlp(params["shared"], x)
    return y, aux.astype(jnp.float32)
