"""Shared building blocks: norms, rotary embeddings, inits, loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def param_dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, weight, eps: float = 1e-6):
    """RMSNorm with fp32 statistics (matches production LM stacks)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + 0.0) * weight.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (default + M-RoPE)
# ---------------------------------------------------------------------------


def _rope_angles(positions, head_dim: int, theta: float):
    """positions: (..., S) int -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / float(half))
    )
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float, mrope_sections=None):
    """Rotate pairs (x[..., :half], x[..., half:]) — GPT-NeoX convention.

    x: (B, S, H, D); positions: (B, S) or (B, S, 3) for M-RoPE.
    For M-RoPE, the ``D/2`` rotary frequencies are split into three sections
    (temporal, height, width), each driven by its own position stream.
    """
    half = x.shape[-1] // 2
    if mrope_sections is not None and positions.ndim == 3:
        cos_parts, sin_parts = [], []
        start = 0
        for sec_i, sec in enumerate(mrope_sections):
            freqs = 1.0 / (
                theta ** (jnp.arange(start, start + sec, dtype=jnp.float32) / half)
            )
            ang = positions[..., sec_i].astype(jnp.float32)[..., None] * freqs
            cos_parts.append(jnp.cos(ang))
            sin_parts.append(jnp.sin(ang))
            start += sec
        cos = jnp.concatenate(cos_parts, axis=-1)
        sin = jnp.concatenate(sin_parts, axis=-1)
    else:
        if positions.ndim == 3:  # M-RoPE positions fed to a default-RoPE layer
            positions = positions[..., 0]
        cos, sin = _rope_angles(positions, x.shape[-1], theta)
    cos = cos[:, :, None, :].astype(x.dtype)  # (B, S, 1, half)
    sin = sin[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in=None):
    fan_in = fan_in or shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels, mask=None):
    """Mean CE over masked positions.  logits: (..., V) promoted to fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
