"""numpy backend of the scheduling-policy protocol (float64 reference).

Absorbed ``repro.core.policies`` (which now re-exports from here).  The
:class:`Policy` object is what ``core.simkernel`` (vectorised tick engine)
and ``core.des`` (exact event-driven oracle) consume:

  * ``keys(state)``          — per-thread composite key (lower runs first):
                               the protocol *primary* key scaled by 1e9 plus
                               this backend's secondary tie-break, the
                               thread-vruntime rank in [0, 1);
  * ``slice_ticks``          — how long an assigned thread keeps its core;
  * ``preempt_cores(state)`` — cores to release early this tick (wakeup /
                               credit / RT preemption, shared hysteresis
                               rule ``protocol.credit_preempt``);
  * ``voluntary_switch(...)``— the per-policy voluntary handoff cost model
                               (run-to-completion vs vruntime-ordered picks)
                               that ``simkernel`` charges every tick.

:func:`primary_key` is the protocol-level key on an :class:`EntityView`;
the JAX backend implements the identical formulas in ``jnp`` and the
differential tests pin both to the same picked sets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.sched.protocol import (
    CFS_DEFAULT_SLICE_TICKS,
    CREDIT_EPS,
    EEVDF_INELIGIBLE,
    RT_BASE,
    TUNED_SLICE_TICKS,
    PolicySpec,
    credit_preempt,
    spec as get_spec,
)

__all__ = [
    "CFS_DEFAULT_SLICE_TICKS", "TUNED_SLICE_TICKS",
    "EntityView", "Policy", "make_policy", "pick_k", "primary_key",
]


@dataclass
class EntityView:
    """Per-entity scheduling state, the protocol's input contract.

    One row per schedulable entity (simulator thread / serving request
    slot); group-level arrays are indexed by ``ent_group``.
    """

    ent_group: np.ndarray  # (T,) int — group (cgroup/function/tenant) id
    group_vrt: np.ndarray  # (G,) group vruntime (seconds of service)
    group_credit: np.ndarray  # (G,) Load Credit
    last_pick_tick: np.ndarray  # (T,) tick of last core/slot assignment
    runnable: np.ndarray  # (T,) bool
    group_runnable: np.ndarray  # (G,) bool — any runnable member
    is_rt_group: np.ndarray  # (G,) bool — pinned SCHED_RR (lags-static)
    tick_sec: float = 0.004
    slice_ticks: int = 1


def primary_key(spec: PolicySpec, v: EntityView) -> np.ndarray:
    """(T,) float64 protocol primary key; lower runs first.

    This is *the* policy definition.  ``jax_backend.primary_key`` mirrors
    it in jnp; keep the two in lockstep (tests/test_sched_backends.py).
    """
    g = v.ent_group
    if spec.kind == "lags":
        return v.group_credit[g].astype(np.float64)
    if spec.kind == "rr":
        # FIFO by last pick: round robin across all entities
        return v.last_pick_tick.astype(np.float64)
    if spec.kind == "lags-static":
        is_rt = v.is_rt_group[g]
        return np.where(is_rt, RT_BASE + v.last_pick_tick,
                        v.group_vrt[g]).astype(np.float64)
    if spec.kind == "eevdf":
        # eligible (vruntime not ahead of the runnable mean) first, then
        # earliest virtual deadline
        vrt = v.group_vrt[g]
        if v.group_runnable.any():
            vmean = float(np.mean(v.group_vrt[v.group_runnable]))
        else:
            vmean = 0.0
        deadline = vrt + spec.slice_ticks * v.tick_sec
        inel = (vrt > vmean + CREDIT_EPS).astype(np.float64)
        return inel * EEVDF_INELIGIBLE + deadline
    # CFS: hierarchical — group vruntime is the primary
    return v.group_vrt[g].astype(np.float64)


def pick_k(keys: np.ndarray, runnable: np.ndarray, k: int) -> np.ndarray:
    """Indices of the k lowest-key runnable entities (stable order)."""
    cand = np.where(runnable)[0]
    return cand[np.argsort(keys[cand], kind="stable")][:k]


@dataclass
class Policy:
    """A :class:`PolicySpec` bound to this backend (+ runtime RT set)."""

    spec: PolicySpec
    static_rt_fns: Optional[np.ndarray] = None

    # -- compat surface (the old repro.core.policies.Policy fields) -------
    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def slice_ticks(self) -> int:
        return self.spec.slice_ticks

    @property
    def credit_window(self) -> int:
        return self.spec.credit_window

    @property
    def lags(self) -> bool:
        return self.spec.kind == "lags"

    @property
    def eevdf(self) -> bool:
        return self.spec.kind == "eevdf"

    @property
    def rr(self) -> bool:
        return self.spec.kind == "rr"

    @property
    def run_to_completion(self) -> bool:
        """Credit-ordered policies hand off within the group (paper §4.3)."""
        return self.spec.kind in ("lags", "lags-static")

    def _rt_mask(self, n_groups: int) -> np.ndarray:
        m = np.zeros(n_groups, bool)
        if self.spec.kind == "lags-static" and self.static_rt_fns is not None:
            m[np.asarray(self.static_rt_fns, np.int64)] = True
        return m

    def view_of(self, st) -> EntityView:
        """Adapt simulator ``_State`` to the protocol's entity view."""
        runnable = st.runnable_mask()
        group_runnable = np.zeros(st.fn_vrt.shape[0], bool)
        group_runnable[np.unique(st.th_fn[runnable])] = True
        return EntityView(
            ent_group=st.th_fn,
            group_vrt=st.fn_vrt,
            group_credit=st.credit,
            last_pick_tick=st.th_last_run / st.tick_sec,
            runnable=runnable,
            group_runnable=group_runnable,
            is_rt_group=self._rt_mask(st.fn_vrt.shape[0]),
            tick_sec=st.tick_sec,
            slice_ticks=self.spec.slice_ticks,
        )

    def keys(self, st) -> np.ndarray:
        """(T,) float64 composite key; lower runs first.

        Protocol primary * 1e9 plus the thread-vruntime rank in [0, 1) as
        secondary, so a single argsort gives hierarchical order.
        """
        T = st.th_fn.shape[0]
        order = np.argsort(st.th_vrt, kind="stable")
        rank = np.empty(T)
        rank[order] = np.arange(T) / max(T, 1)
        return primary_key(self.spec, self.view_of(st)) * 1e9 + rank

    def preempt_cores(self, st) -> np.ndarray:
        """Indices of cores to release for a waiting lower-key thread."""
        running = st.core_thread >= 0
        if not running.any():
            return np.empty(0, np.int64)
        wait_mask = st.waiting_mask()
        if not wait_mask.any():
            return np.empty(0, np.int64)
        run_fn = st.th_fn[np.maximum(st.core_thread, 0)]
        if self.spec.kind == "lags":
            # paper §4.3 global path: a waking task of a lighter cgroup
            # takes the core running the heaviest-credit task, subject to
            # the configured hysteresis gap.
            wait_credit = float(st.credit[st.th_fn[wait_mask]].min())
            run_credit = np.where(running, st.credit[run_fn], -np.inf)
            worst = int(np.argmax(run_credit))
            if credit_preempt(wait_credit, float(run_credit[worst]),
                              self.spec.preempt_hysteresis):
                return np.asarray([worst])
            return np.empty(0, np.int64)
        is_rt = self._rt_mask(st.fn_vrt.shape[0])
        if is_rt.any():
            # RT tasks preempt CFS tasks immediately
            if is_rt[st.th_fn[wait_mask]].any():
                run_is_cfs = running & ~is_rt[run_fn]
                idx = np.where(run_is_cfs)[0]
                return idx[:1]
            return np.empty(0, np.int64)
        # CFS / EEVDF wakeup preemption: waiting group vrt far behind running
        gran = st.tick_sec  # wakeup_granularity ~ one tick
        wait_v = st.fn_vrt[st.th_fn[wait_mask]].min()
        run_v = np.where(running, st.fn_vrt[run_fn], -np.inf)
        worst = int(np.argmax(run_v))
        if wait_v + gran < run_v[worst]:
            return np.asarray([worst])
        return np.empty(0, np.int64)

    def voluntary_switch(self, st, run_fn, sibs, c_same, c_cross, cost_cfs,
                         p_preempt):
        """Per-policy voluntary (block/wake) handoff cost and switch rate.

        Returns ``(cost_us, spb)``: the per-handoff cost for each running
        core and the switches-per-burst multiplier.  Under run-to-completion
        policies, cores serving the current lightest groups hand off within
        the group (leaf-rq-only re-insert; a sole runnable sibling is
        re-picked switch-free) and credit-ordered picking fires wakeup
        preemption less often than CFS's vruntime ordering.
        """
        if self.run_to_completion:
            run_credit = st.credit[run_fn]
            wait_m = st.waiting_mask()
            if wait_m.any():
                w_cmin = st.credit[st.th_fn[wait_m]].min()
            else:
                w_cmin = np.inf
            in_order = run_credit <= w_cmin + CREDIT_EPS
            solo = sibs <= 1.0
            cost = np.where(in_order & solo, 0.0,
                            np.where(in_order, c_same, cost_cfs))
            return cost, 1.0 + 0.85 * p_preempt
        return cost_cfs, 1.0 + p_preempt

    def request_key(self, credit, fn_vrt, fn: int, arrival: float, idx: int):
        """Request-granularity key for the exact DES oracle."""
        if self.spec.kind == "lags":
            return (credit[fn], arrival, idx)
        if self.spec.kind == "rr":
            return (arrival, idx)
        return (fn_vrt[fn], arrival, idx)


def make_policy(name: str, **kw) -> Policy:
    """Registry-backed factory (the former if/elif chain)."""
    static_rt = kw.pop("static_rt_fns", None)
    spec = get_spec(name, **kw)
    if static_rt is not None:
        spec = spec.with_overrides(
            static_rt_fns=tuple(int(f) for f in np.asarray(static_rt).ravel())
        )
        static_rt = np.asarray(static_rt, np.int64)
    return Policy(spec=spec, static_rt_fns=static_rt)
