"""Admission-policy backends for the continuous-batching serving engine.

The engine's "context switch" is a batch-membership change; these policies
decide which queued requests claim free batch slots and when a waiting
tenant may evict a running one.  The LAGS credit ordering and hysteresis
preemption here are the *same protocol rules* the node simulators use
(``protocol.credit_preempt``; ascending Load Credit, run-to-completion) —
previously ``scheduler/admission.py`` carried its own copy with a magic
0.5 constant, now a config field (``EngineConfig.preempt_hysteresis``).

``scheduler.admission`` keeps the stable entry points and delegates here
via :func:`admission_policy` (registry lookup, no string dispatch in the
consumer).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.sched.protocol import credit_preempt


class AdmissionPolicy:
    """Protocol: order waiting tenants, fill slots, decide preemption."""

    name = "base"
    #: drain a chosen tenant's whole queue before moving on (LAGS
    #: run-to-completion) instead of admitting round-robin
    drain = False

    def order(self, waiting: List) -> List:
        raise NotImplementedError

    def pick(self, tenants: Dict[int, object], free_slots: int,
             running_tenants: set) -> List:
        """Choose queued requests to admit into the free batch slots."""
        waiting = [t for t in tenants.values() if t.queue]
        if not waiting or free_slots <= 0:
            return []
        order = self.order(waiting)
        out: List = []
        if self.drain:
            for t in order:
                while t.queue and len(out) < free_slots:
                    out.append(t.queue.popleft())
                if len(out) >= free_slots:
                    break
        else:
            # round-robin one per tenant until slots exhausted
            while len(out) < free_slots:
                progressed = False
                for t in order:
                    if t.queue and len(out) < free_slots:
                        out.append(t.queue.popleft())
                        progressed = True
                if not progressed:
                    break
        return out

    def preempt(self, tenants: Dict[int, object], running_tenants: set,
                hysteresis: float) -> Tuple[bool, int]:
        return False, -1


class FifoAdmission(AdmissionPolicy):
    """Arrival order, no tenant-awareness (baseline)."""

    name = "fifo"

    def pick(self, tenants, free_slots, running_tenants):
        waiting = [t for t in tenants.values() if t.queue]
        if not waiting or free_slots <= 0:
            return []
        reqs = sorted((t.queue[0] for t in waiting), key=lambda r: r.arrival)
        out = []
        for r in reqs[:free_slots]:
            tenants[r.tenant].queue.popleft()
            out.append(r)
        return out


class FairAdmission(AdmissionPolicy):
    """CFS analogue: least-recently-admitted round robin — maximal
    fairness, maximal batch churn."""

    name = "fair"

    def order(self, waiting):
        return sorted(waiting, key=lambda t: (t.last_admit, t.tid))


class LagsAdmission(AdmissionPolicy):
    """The paper's policy: lowest Load Credit first, run-to-completion.

    Admit the lightest-credit tenant and drain its queue before moving on;
    evict a running tenant only on a clear credit gap (hysteresis), else
    keep running to completion over the credit window.  Fewer membership
    changes -> fewer engine context switches (weight swaps, page churn,
    re-dispatch).
    """

    name = "lags"
    drain = True

    def order(self, waiting):
        return sorted(waiting, key=lambda t: (t.credit, t.tid))

    def preempt(self, tenants, running_tenants, hysteresis):
        """LAGS global path: a waiting tenant lighter than a running one
        (by more than the hysteresis gap) may claim a slot."""
        waiting = [t for t in tenants.values() if t.queue]
        if not waiting or not running_tenants:
            return False, -1
        lightest_wait = min(waiting, key=lambda t: (t.credit, t.tid))
        heaviest_run = max(
            (tenants[tid] for tid in running_tenants),
            key=lambda t: (t.credit, -t.tid),
        )
        if credit_preempt(lightest_wait.credit, heaviest_run.credit,
                          hysteresis):
            return True, heaviest_run.tid
        return False, -1


ADMISSION: Dict[str, AdmissionPolicy] = {
    p.name: p for p in (FifoAdmission(), FairAdmission(), LagsAdmission())
}


def admission_policy(name: str) -> AdmissionPolicy:
    try:
        return ADMISSION[name]
    except KeyError:
        raise ValueError(f"unknown admission policy {name!r}") from None
