"""JAX backend of the scheduling-policy protocol (jit/vmap/pjit-safe).

Pure ``jnp`` mirrors of ``numpy_backend.primary_key`` plus the per-policy
voluntary switch-cost model, consumed by ``core.simkernel_jax`` so that
**all** policy kinds — CFS, EEVDF, SCHED_RR, CFS-LAGS, CFS-LAGS-static
(and the tuned-slice variants) — run under ``lax.scan`` and shard across
the cluster mesh.  Policy codes are static jit arguments, so dispatch is
plain Python at trace time: the scan body contains no policy branches.

Secondary tie-break in this backend: the slot id (added as ``idx * eps``
by the simulator); the numpy backend uses thread-vruntime rank instead.
Primary keys are identical across backends — that is the contract the
differential tests pin (``tests/test_sched_backends.py``).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax.numpy as jnp

from repro.sched.protocol import (
    CREDIT_EPS,
    EEVDF_INELIGIBLE,
    RT_BASE,
    PolicySpec,
    spec as get_spec,
)

# Static policy codes (jit static args).  CFS/LAGS keep their historical
# values from the two-policy simulator.
CFS, LAGS, EEVDF, RR, LAGS_STATIC, CFS_TUNED, EEVDF_TUNED = range(7)

CODE_OF = {
    "cfs": CFS, "lags": LAGS, "eevdf": EEVDF, "rr": RR,
    "lags-static": LAGS_STATIC, "cfs-tuned": CFS_TUNED,
    "eevdf-tuned": EEVDF_TUNED,
}
NAME_OF = {v: k for k, v in CODE_OF.items()}


def spec_of(code: int, **overrides) -> PolicySpec:
    return get_spec(NAME_OF[code], **overrides)


class PolicyView(NamedTuple):
    """Per-tick scheduling state handed to the key functions.

    Entity-level arrays are (T,) over request slots; group-level arrays
    are (G,) over function/tenant cgroups, gathered via ``ent_group``.
    """

    ent_group: jnp.ndarray  # (T,) int32
    group_vrt: jnp.ndarray  # (G,)
    group_credit: jnp.ndarray  # (G,)
    last_pick_tick: jnp.ndarray  # (T,)
    runnable: jnp.ndarray  # (T,) bool
    group_runnable: jnp.ndarray  # (G,) bool
    is_rt_group: jnp.ndarray  # (G,) bool
    tick_sec: float  # python scalar (static)
    slice_ticks: int  # python scalar (static)


def primary_key(code: int, v: PolicyView) -> jnp.ndarray:
    """(T,) primary key, lower runs first — jnp mirror of numpy_backend."""
    g = v.ent_group
    if code == LAGS:
        return v.group_credit[g]
    if code == RR:
        return v.last_pick_tick.astype(jnp.float32)
    if code == LAGS_STATIC:
        is_rt = v.is_rt_group[g]
        return jnp.where(is_rt, RT_BASE + v.last_pick_tick, v.group_vrt[g])
    if code in (EEVDF, EEVDF_TUNED):
        vrt = v.group_vrt[g]
        n_run = jnp.maximum(jnp.sum(v.group_runnable), 1)
        vmean = jnp.sum(jnp.where(v.group_runnable, v.group_vrt, 0.0)) / n_run
        deadline = vrt + v.slice_ticks * v.tick_sec
        inel = (vrt > vmean + CREDIT_EPS).astype(vrt.dtype)
        return inel * EEVDF_INELIGIBLE + deadline
    # CFS / CFS_TUNED
    return v.group_vrt[g]


def sticky_mask(code: int, v: PolicyView, continuing: jnp.ndarray
                ) -> jnp.ndarray:
    """Which slice-holding slots keep their core this tick.

    ``continuing`` = picked last tick, slice not expired, still runnable.
    Credit preemption (LAGS) and RT wakeups (LAGS-static) break slices:
    a strictly lighter waiting group / a waiting RT task voids stickiness
    so the top-k pick can reclaim the core — the same rules the numpy
    backend applies in ``Policy.preempt_cores``.
    """
    if code == LAGS:
        waiting = v.runnable & ~continuing
        wait_cmin = jnp.min(
            jnp.where(waiting, v.group_credit[v.ent_group], jnp.inf)
        )
        lighter_waits = v.group_credit[v.ent_group] > wait_cmin + CREDIT_EPS
        return continuing & ~lighter_waits
    if code == LAGS_STATIC:
        is_rt = v.is_rt_group[v.ent_group]
        rt_waiting = jnp.any(v.runnable & ~continuing & is_rt)
        return continuing & (is_rt | ~rt_waiting)
    # CFS/EEVDF slices are one tick by default; tuned variants and RR hold
    # the full quantum (wakeup preemption is folded into the burst model).
    return continuing


def voluntary_switch(code: int, *, c_same, c_cross, cost_cfs, run_credit,
                     wait_cmin, sibs, p_preempt) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-policy voluntary handoff cost + switches-per-burst multiplier.

    jnp mirror of ``numpy_backend.Policy.voluntary_switch``: under
    run-to-completion (LAGS kinds) cores serving in credit order hand off
    within the group, a sole runnable sibling is re-picked switch-free,
    and credit-based wakeup preemption fires less often than CFS's.
    """
    if code in (LAGS, LAGS_STATIC):
        in_order = run_credit <= wait_cmin + CREDIT_EPS
        solo = sibs <= 1.0
        cost = jnp.where(in_order & solo, 0.0,
                         jnp.where(in_order, c_same, cost_cfs))
        return cost, 1.0 + 0.85 * p_preempt
    return cost_cfs, 1.0 + p_preempt


def key_fn(code: int) -> Callable[[PolicyView], jnp.ndarray]:
    if code not in NAME_OF:
        raise ValueError(f"unknown policy code {code!r}")
    return lambda v: primary_key(code, v)
