"""The unified scheduling-policy protocol (paper §4, one definition).

Every scheduler in this repo — the numpy tick simulator, the exact DES
oracle, the ``lax.scan`` cluster simulator and the serving engine's
admission scheduler — used to carry its own copy of the policy logic.
This module is the single source of truth they now share.

A *policy* is described by a :class:`PolicySpec` and consists of:

  * per-entity state arrays owned by the caller (group vruntime, Load
    Credit, last-pick time, runnable/running masks);
  * a composite **key** — lower runs first — whose *primary* component is
    defined once per policy kind (see the backend modules; each backend may
    add its own deterministic secondary tie-break);
  * a **slice length** in scheduler ticks (how long a picked entity keeps
    its core / batch slot);
  * a **preemption rule** — for credit-based policies the shared
    :func:`credit_preempt` hysteresis comparison.

Backends:

  * ``repro.sched.numpy_backend`` — the float64 reference ``Policy`` used
    by ``core.simkernel`` and ``core.des`` (absorbed ``core.policies``);
  * ``repro.sched.jax_backend``   — pure ``jnp`` key / voluntary-cost
    functions that jit, ``vmap`` and shard, driving
    ``core.simkernel_jax`` for **all** policy kinds;
  * ``repro.sched.pallas_backend`` — the fused Load-Credit tick +
    k-lowest-credit selection TPU kernel (``kernels.lags_select``) behind
    the serving engine's admission path at high tenant counts;
  * ``repro.sched.serving``       — admission-policy registry (fifo /
    fair / lags) for the continuous-batching engine.

``tests/test_sched_backends.py`` is the cross-backend differential gate:
numpy, JAX and Pallas must agree on scheduling decisions (identical
picked / preempted sets) on randomized small cases.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

from repro.core.load_credit import DEFAULT_EMA_WINDOW

# scheduler tick = 4 ms (CONFIG_HZ = 250)
CFS_DEFAULT_SLICE_TICKS = 1  # min_granularity ~3 ms -> 1 tick under load
TUNED_SLICE_TICKS = 25  # 100 ms (fig 11 "tuned" baselines / SCHED_RR quantum)

# Policy kinds: the primary-key families.
KINDS = ("cfs", "eevdf", "rr", "lags", "lags-static")

# Key-composition constants shared by every backend.  RT entities sort at
# RT_BASE + last-pick-tick: far below any CFS vruntime, FIFO within RT.
RT_BASE = -1e7
# EEVDF: ineligible entities (vruntime ahead of the runnable mean) sort
# after every eligible one by this offset on the primary key.  Kept small
# enough (>> any virtual deadline in seconds, << 1e6) that the composite
# key primary*1e9 + rank still resolves the secondary tie-break in
# float64 — the old 1e15-scale offset quantized it away at the ulp.
EEVDF_INELIGIBLE = 1e4
# Strict-inequality slack for credit comparisons (float noise guard).
CREDIT_EPS = 1e-12


@dataclass(frozen=True)
class PolicySpec:
    """Declarative policy description consumed by every backend."""

    name: str
    kind: str  # one of KINDS
    slice_ticks: int = CFS_DEFAULT_SLICE_TICKS
    credit_window: int = DEFAULT_EMA_WINDOW
    # LAGS preemption hysteresis: a waiting group preempts a running one
    # only when wait_credit < hysteresis * run_credit.  The node simulators
    # use 1.0 (paper §4.3 global path: any strictly lighter waker wins);
    # the serving engine defaults to 0.5 (EngineConfig.preempt_hysteresis)
    # because an engine membership change is far costlier than a kernel
    # task switch, so it demands a clear credit gap.
    preempt_hysteresis: float = 1.0
    # lags-static: function/tenant ids pinned under SCHED_RR priority
    static_rt_fns: Optional[Tuple[int, ...]] = None

    def with_overrides(self, **kw) -> "PolicySpec":
        return replace(self, **kw)


_REGISTRY: Dict[str, PolicySpec] = {}


def register(spec: PolicySpec) -> PolicySpec:
    if spec.kind not in KINDS:
        raise ValueError(f"unknown policy kind {spec.kind!r}")
    _REGISTRY[spec.name] = spec
    return spec


def spec(name: str, **overrides) -> PolicySpec:
    """Registry lookup (the former string dispatch, in one place)."""
    try:
        base = _REGISTRY[name.lower()]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}") from None
    return base.with_overrides(**overrides) if overrides else base


def names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


register(PolicySpec("cfs", "cfs"))
register(PolicySpec("cfs-tuned", "cfs", slice_ticks=TUNED_SLICE_TICKS))
register(PolicySpec("eevdf", "eevdf"))
register(PolicySpec("eevdf-tuned", "eevdf", slice_ticks=TUNED_SLICE_TICKS))
register(PolicySpec("rr", "rr", slice_ticks=TUNED_SLICE_TICKS))
register(PolicySpec("lags", "lags"))
register(PolicySpec("lags-static", "lags-static",
                    slice_ticks=TUNED_SLICE_TICKS))


def credit_preempt(wait_min_credit: float, run_max_credit: float,
                   hysteresis: float) -> bool:
    """The one LAGS preemption rule (paper §4.3 global path).

    A waking entity of the lightest waiting group claims a core/slot held
    by the heaviest running group iff its credit is below
    ``hysteresis * run_max_credit`` by more than float noise.  Hysteresis
    1.0 = preempt on any strictly lighter waiter (node scheduler);
    < 1.0 = demand a clear gap before paying a membership change (engine).
    """
    return wait_min_credit < hysteresis * run_max_credit - CREDIT_EPS
