"""Pallas backend: fused Load-Credit tick + k-lowest-credit selection.

Wraps the TPU kernel ``repro.kernels.lags_select`` (PELT + Load Credit EMA
update followed by top-k-lowest selection — ``pick_next_task_fair``
vectorised) as the scheduling-policy protocol's third backend.  The
serving engine routes its per-step credit tick through this path once the
tenant count crosses ``EngineConfig.pallas_threshold``: one kernel launch
replaces the O(T) Python EMA loop, and the returned pick order is exactly
the LAGS admission order the engine applies next step.

Off-TPU the kernel runs in Pallas interpret mode (bit-compatible, slow) —
``tick_and_pick`` picks the mode from the active JAX backend, so tests and
CPU smoke runs exercise the identical kernel code path.

``numpy_reference`` is the float64 oracle for the cross-backend
differential tests.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.load_credit import (
    DEFAULT_EMA_WINDOW,
    PELT_HALFLIFE_TICKS,
    ema_update,
    pelt_update,
)


def available() -> bool:
    try:
        import jax  # noqa: F401
        from jax.experimental import pallas  # noqa: F401
    except Exception:  # pragma: no cover - jax is baked into the image
        return False
    return True


def _interpret_default() -> bool:
    import jax

    return jax.default_backend() != "tpu"


def tick_and_pick(load_avg, credit, running_frac, runnable, k: int, *,
                  window: int = DEFAULT_EMA_WINDOW,
                  halflife: int = PELT_HALFLIFE_TICKS,
                  interpret: bool | None = None):
    """One scheduler tick over T groups on the Pallas kernel.

    Returns ``(new_load (T,), new_credit (T,), picked_idx (k,) int32)``
    with -1 padding when fewer than k groups are runnable.  Picked order
    is ascending updated credit, ties broken by group index — identical
    to the numpy backend's LAGS admission order.
    """
    import jax.numpy as jnp

    from repro.kernels.lags_select import lags_select

    if interpret is None:
        interpret = _interpret_default()
    nl, nc, idx = lags_select(
        jnp.asarray(load_avg, jnp.float32),
        jnp.asarray(credit, jnp.float32),
        jnp.asarray(running_frac, jnp.float32),
        jnp.asarray(runnable),
        k, window=window, halflife=halflife, interpret=interpret,
    )
    return np.asarray(nl), np.asarray(nc), np.asarray(idx)


def numpy_reference(load_avg, credit, running_frac, runnable, k: int, *,
                    window: int = DEFAULT_EMA_WINDOW,
                    halflife: int = PELT_HALFLIFE_TICKS
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """float64 oracle: same tick + selection via the numpy protocol path."""
    y = 0.5 ** (1.0 / halflife)
    new_load = pelt_update(np.asarray(load_avg, np.float64),
                           np.asarray(running_frac, np.float64), y)
    new_credit = ema_update(np.asarray(credit, np.float64), new_load, window)
    runnable = np.asarray(runnable, bool)
    order = [i for i in np.lexsort((np.arange(len(new_credit)), new_credit))
             if runnable[i]][:k]
    picked = np.full(k, -1, np.int32)
    picked[: len(order)] = order
    return new_load, new_credit, picked
