"""repro.sched — the unified scheduling policy core.

One policy protocol (``protocol.PolicySpec``: per-entity state arrays, a
composite lower-runs-first key, a slice length, a preemption rule) with
three interchangeable backends — numpy (simulators/DES), JAX (lax.scan
cluster simulator, all policies jit/vmap/pjit) and Pallas (fused credit
tick + selection kernel behind the serving engine's admission path) —
plus the serving admission registry.  See each submodule's docstring.
"""
from repro.sched.protocol import (  # noqa: F401
    CFS_DEFAULT_SLICE_TICKS,
    KINDS,
    TUNED_SLICE_TICKS,
    PolicySpec,
    credit_preempt,
    names,
    register,
    spec,
)
from repro.sched.numpy_backend import (  # noqa: F401
    EntityView,
    Policy,
    make_policy,
    pick_k,
    primary_key,
)
