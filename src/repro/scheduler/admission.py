"""Admission entry points for the continuous-batching engine.

Thin facade over the unified policy core: the actual admission policies
(``fifo`` / ``fair`` / ``lags``) live in ``repro.sched.serving`` and are
resolved by registry lookup — no policy-specific branching here.  The
LAGS credit ordering and hysteresis preemption are the same protocol
rules the node simulators use (``repro.sched.protocol.credit_preempt``);
the hysteresis is a caller-supplied config value
(``EngineConfig.preempt_hysteresis``), not a constant.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.obs import metrics as obs_metrics
from repro.scheduler.tenant import Request, Tenant
from repro.sched.serving import admission_policy

#: engine default: demand a clear credit gap before paying a membership
#: change (an engine batch re-formation is far costlier than a kernel
#: task switch, so the engine is more reluctant than the node's 1.0)
DEFAULT_PREEMPT_HYSTERESIS = 0.5


def pick_admissions(
    policy: str,
    tenants: Dict[int, Tenant],
    free_slots: int,
    running_tenants: set,
) -> List[Request]:
    """Choose queued requests to admit into the free batch slots."""
    out = admission_policy(policy).pick(tenants, free_slots, running_tenants)
    if out:
        obs_metrics.counter(f"admission.{policy}.admitted").inc(len(out))
    return out


def should_preempt(
    policy: str,
    tenants: Dict[int, Tenant],
    running_tenants: set,
    hysteresis: float = DEFAULT_PREEMPT_HYSTERESIS,
) -> Tuple[bool, int]:
    """LAGS global path: a waiting tenant lighter than a running one (by
    more than the hysteresis gap) may claim a slot
    (returns (True, victim_tid))."""
    fire, victim = admission_policy(policy).preempt(
        tenants, running_tenants, hysteresis
    )
    if fire:
        obs_metrics.counter(f"admission.{policy}.preemptions").inc()
    return fire, victim
