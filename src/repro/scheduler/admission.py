"""Admission policies for the continuous-batching engine.

``fair``  — the CFS analogue: tenants are admitted in attained-service order
            (vruntime-equal share), preempting the batch membership whenever
            a less-served tenant waits: maximal fairness, maximal batch churn.
``lags``  — the paper's policy: admit requests from the tenant with the
            LOWEST Load Credit and keep its requests running to completion
            as long as no lighter tenant is waiting (run-to-completion over
            the credit window).  Fewer membership changes -> fewer engine
            "context switches" (weight swaps, page churn, re-dispatch).
``fifo``  — arrival order, no tenant-awareness (baseline).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.obs import metrics as obs_metrics
from repro.scheduler.tenant import Request, Tenant


def pick_admissions(
    policy: str,
    tenants: Dict[int, Tenant],
    free_slots: int,
    running_tenants: set,
) -> List[Request]:
    """Choose queued requests to admit into the free batch slots."""
    waiting = [t for t in tenants.values() if t.queue]
    if not waiting or free_slots <= 0:
        return []

    if policy == "fifo":
        reqs = sorted(
            (t.queue[0] for t in waiting), key=lambda r: r.arrival
        )
        out = []
        for r in reqs[:free_slots]:
            tenants[r.tenant].queue.popleft()
            out.append(r)
        obs_metrics.counter(f"admission.{policy}.admitted").inc(len(out))
        return out

    if policy == "fair":
        # CFS analogue: round-robin admission, least-recently-admitted first
        order = sorted(waiting, key=lambda t: (t.last_admit, t.tid))
    elif policy == "lags":
        # lowest Load Credit first; drain that tenant's whole queue before
        # moving on (run-to-completion)
        order = sorted(waiting, key=lambda t: (t.credit, t.tid))
    else:
        raise ValueError(f"unknown admission policy {policy!r}")

    out: List[Request] = []
    if policy == "lags":
        for t in order:
            while t.queue and len(out) < free_slots:
                out.append(t.queue.popleft())
            if len(out) >= free_slots:
                break
    else:
        # round-robin one per tenant until slots exhausted
        while len(out) < free_slots:
            progressed = False
            for t in order:
                if t.queue and len(out) < free_slots:
                    out.append(t.queue.popleft())
                    progressed = True
            if not progressed:
                break
    obs_metrics.counter(f"admission.{policy}.admitted").inc(len(out))
    return out


def should_preempt(
    policy: str, tenants: Dict[int, Tenant], running_tenants: set
) -> Tuple[bool, int]:
    """LAGS global path: a waiting tenant lighter than a running one may
    claim a slot (returns (True, victim_tid))."""
    waiting = [t for t in tenants.values() if t.queue]
    if not waiting or not running_tenants:
        return False, -1
    if policy != "lags":
        return False, -1
    lightest_wait = min(waiting, key=lambda t: t.credit)
    heaviest_run = max(
        (tenants[tid] for tid in running_tenants), key=lambda t: t.credit
    )
    # hysteresis: evict only on a clear credit gap, else run-to-completion
    if lightest_wait.credit < 0.5 * heaviest_run.credit - 1e-12:
        obs_metrics.counter("admission.lags.preemptions").inc()
        return True, heaviest_run.tid
    return False, -1
