"""Tenant (serving-side cgroup analogue) and request state.

A tenant is a hosted function/model variant; its Load Credit is the EMA of
*attained accelerator service* (device-seconds), updated once per engine
step — the direct analogue of ``tg->load_avg_ema`` with engine steps as
scheduler ticks (DESIGN.md §2 table).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Optional

from repro.core.load_credit import ema_update, pelt_update


@dataclass
class Request:
    rid: int
    tenant: int
    prompt_len: int
    max_new: int
    arrival: float
    generated: int = 0
    prefilled: bool = False
    start_time: float = -1.0
    finish_time: float = -1.0
    # graceful-degradation state (repro.serving.engine): out-of-pages
    # rejections so far, the engine time before which the request is parked
    # (exponential backoff), and whether overload shedding already halved
    # its ``max_new`` (truncation is applied at most once per request)
    rejections: int = 0
    backoff_until: float = 0.0
    truncated: bool = False

    @property
    def done(self) -> bool:
        return self.generated >= self.max_new

    @property
    def latency(self) -> float:
        return self.finish_time - self.arrival if self.finish_time >= 0 else -1.0


@dataclass
class Tenant:
    tid: int
    name: str = ""
    weight_mb: float = 64.0  # adapter/weight bytes swapped in on admission
    queue: Deque[Request] = field(default_factory=deque)
    load_avg: float = 0.0
    credit: float = 0.0
    resident: bool = False  # weights currently on device
    served_s: float = 0.0
    last_admit: float = -1.0  # round-robin pointer for the fair policy

    def tick(self, service_s: float, step_s: float, window: int = 256):
        """Update Load Credit with this step's attained service."""
        frac = service_s / max(step_s, 1e-9)
        self.load_avg = pelt_update(self.load_avg, frac)
        self.credit = ema_update(self.credit, self.load_avg, window)
        self.served_s += service_s
