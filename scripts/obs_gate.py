#!/usr/bin/env python
"""Observability regression gate: telemetry off must stay free.

Runs the density-9 simkernel workload (108 functions on 12 HT, the
paper's peak-throughput density) with telemetry *disabled* and fails if
it regresses >3% against the baseline pinned in
``benchmarks/obs_gate_baseline.json``.

Wall-clock alone is machine-dependent, so the gate times a fixed numpy
calibration workload on the same machine and compares the *ratio*
sim_time / calib_time against the stored ratio — both sides are
numpy-bound, so the ratio transfers across hosts.  Both measurements
take the best of several repetitions to shed scheduler noise.

The baseline also pins a behavioral fingerprint (completions, switches,
busy seconds) of the same seeded run: a fingerprint mismatch means the
simulator's *behavior* changed, which is a different failure than a
performance regression and is reported as such.

A second, fleet-level fingerprint pins the ``repro.fleet`` layer: a
3-node density-9 sweep (324 functions placed by round-robin / pack /
spread, policy lags) records each placement's node counts and the fleet
completion/switch/busy totals, so placement or consolidation behavior
cannot drift silently either.  Two chaos fingerprints pin the failure
path: a scripted 2-node crash (legacy grammar) and a 4-node/2-rack
partition + rack-crash run whose per-epoch live/suspect/fenced/draining
ladder, migrations and deferred/reconciled totals must stay exact.

Usage (from the repo root, PYTHONPATH=src):

  python scripts/obs_gate.py            # check against the baseline
  python scripts/obs_gate.py --update   # re-pin after an intended change

``OBS_GATE_TOL`` overrides the relative tolerance (default 0.03).
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

import numpy as np

BASELINE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), os.pardir,
    "benchmarks", "obs_gate_baseline.json",
)

DENSITY = 9
N_CORES = 12
N_FNS = DENSITY * N_CORES
DUR_S = 10.0  # simulated seconds
SEED = 5
REPS = 3  # interleaved sim/calib repetitions per measurement pass
PASSES = 3  # ratio = median over passes (sheds per-pass noise)


def _calib_once() -> float:
    """CPU seconds for a fixed reference workload.

    Deliberately matches the simkernel's instruction mix — a Python loop
    over small-array key composition, top-k selection and scatter-adds —
    rather than one large BLAS call, so the sim/calib ratio stays stable
    under frequency scaling and cache pressure.
    """
    rng = np.random.default_rng(0)
    n = N_FNS * 192  # entity count of the azure2021 density-9 workload,
    # so the calibration's working set leaves cache and tracks the same
    # memory-bandwidth sensitivity as the simulator
    credit = rng.random(N_FNS)
    rank = rng.random(n)
    grp = rng.integers(0, N_FNS, n)
    t0 = time.process_time()
    for _ in range(800):
        keys = credit[grp] * 1e9 + rank
        picked = np.argpartition(keys, N_CORES)[:N_CORES]
        add = np.zeros(N_FNS)
        np.add.at(add, grp[picked], 1.0)
        credit = credit * 0.999 + add * 1e-4
    return time.process_time() - t0


def _sim_once():
    from repro.core.policies import make_policy
    from repro.core.simkernel import SimConfig, simulate
    from repro.core.traces import make_workload

    wl = make_workload("azure2021", N_FNS, duration_s=DUR_S,
                       n_cores=N_CORES, seed=SEED)
    t0 = time.process_time()
    r = simulate(wl, make_policy("lags"), SimConfig(n_cores=N_CORES))
    dt = time.process_time() - t0
    fp = {
        "n_completed": int(r.n_completed),
        "switches": int(r.switches),
        "busy_time_s": round(float(r.busy_time_s), 6),
    }
    return dt, fp


FLEET_NODES = 3
FLEET_PLACEMENTS = ("round-robin", "pack", "spread")
FLEET_DUR_S = 5.0


def fleet_fingerprint():
    """Deterministic 3-node density-9 fleet sweep (behavior, not timing)."""
    from repro.fleet import make_policy, place, simulate_fleet

    fp = {}
    for name in FLEET_PLACEMENTS:
        asg = place(name, FLEET_NODES * N_FNS, FLEET_NODES, n_cores=N_CORES,
                    policy=make_policy("lags"), seed=SEED)
        fleet = simulate_fleet("lags", asg, duration_s=FLEET_DUR_S,
                               n_cores=N_CORES, seed=SEED)
        fp[name] = {
            "counts": asg.counts.tolist(),
            "completed": int(fleet.n_completed),
            "switches": int(sum(r.switches for r in fleet.nodes)),
            "busy_s": round(sum(r.busy_time_s for r in fleet.nodes), 6),
        }
    return fp


CHAOS_NODES = 2
CHAOS_FNS = 24
CHAOS_DUR_S = 9.0
CHAOS_EPOCH_S = 1.5
CHAOS_CRASH_NODE = 1
CHAOS_CRASH_T = 3.0
CHAOS_SEED = 10


def chaos_fingerprint():
    """Deterministic failover run (behavior, not timing): a 2-node fleet
    with a scripted mid-run crash must keep re-placing, charging and
    replaying exactly the same way — per-epoch node counts, migration
    count and completions are pinned."""
    from repro.fleet import FaultSchedule, place, simulate_fleet_chaos

    asg = place("spread", CHAOS_FNS, CHAOS_NODES, n_cores=N_CORES,
                exec_s=0.1)
    res = simulate_fleet_chaos(
        "lags", asg,
        FaultSchedule.single_crash(CHAOS_CRASH_NODE, CHAOS_CRASH_T,
                                   CHAOS_NODES),
        duration_s=CHAOS_DUR_S, epoch_s=CHAOS_EPOCH_S, n_cores=N_CORES,
        seed=CHAOS_SEED, exec_s=0.1,
    )
    return {
        "per_epoch_counts": res.per_epoch_counts(),
        "migrations": len(res.migrations),
        "completed": int(res.n_completed),
        "stranded": int(res.stranded_arrivals),
        "replayed": int(res.replayed_arrivals),
    }


TOPO_NODES = 4
TOPO_RACK_SIZE = 2
TOPO_FNS = 48
TOPO_PART_NODE = 0
TOPO_PART_T = 1.5
TOPO_PART_DUR = 3.0
TOPO_CRASH_RACK = 1
TOPO_CRASH_T = 4.5


def chaos_topology_fingerprint():
    """Deterministic topology-aware chaos run (behavior, not timing): a
    4-node/2-rack fleet where node 0 partitions (SUSPECT -> fenced ->
    healed) and rack 1 then loses both nodes.  Pins the liveness ladder —
    per-epoch live/suspect/fenced/draining counts — plus per-epoch node
    fn counts, migrations, completions and the deferred/reconciled
    reconciliation totals, so detection, fencing or failover drift cannot
    land silently."""
    from repro.fleet import (
        FaultEvent, FaultSchedule, Topology, place, simulate_fleet_chaos,
    )

    topo = Topology.uniform(TOPO_NODES, TOPO_RACK_SIZE)
    sched = FaultSchedule(
        [
            FaultEvent(TOPO_PART_T, "partition", nodes=(TOPO_PART_NODE,),
                       duration=TOPO_PART_DUR),
            FaultEvent(TOPO_CRASH_T, "rack_crash", rack=TOPO_CRASH_RACK),
        ],
        TOPO_NODES, topo,
    )
    asg = place("rack-spread", TOPO_FNS, TOPO_NODES, n_cores=N_CORES,
                exec_s=0.1, racks=topo.racks())
    res = simulate_fleet_chaos(
        "lags", asg, sched, duration_s=CHAOS_DUR_S, epoch_s=CHAOS_EPOCH_S,
        n_cores=N_CORES, seed=CHAOS_SEED, exec_s=0.1, topology=topo,
    )
    return {
        "per_epoch_counts": res.per_epoch_counts(),
        "per_epoch_liveness": res.per_epoch_liveness(),
        "migrations": len(res.migrations),
        "completed": int(res.n_completed),
        "deferred": int(res.deferred_arrivals),
        "reconciled": int(res.reconciled_completions),
        "replayed": int(res.replayed_arrivals),
        "lost": int(res.lost_arrivals),
    }


def measure():
    from repro.obs import metrics

    if metrics.enabled():
        print("obs_gate: telemetry is enabled; this gate times the "
              "disabled path", file=sys.stderr)
        sys.exit(2)
    # CPU time (not wall) sheds other-process interference; interleaving
    # sim and calibration reps makes frequency drift hit both sides alike
    sim_best, calib_best, fp = float("inf"), float("inf"), None
    gc.collect()
    gc.disable()
    try:
        for _ in range(REPS):
            calib_best = min(calib_best, _calib_once())
            dt, fp = _sim_once()
            sim_best = min(sim_best, dt)
            gc.collect()
    finally:
        gc.enable()
    return {"sim_s": sim_best, "calib_s": calib_best,
            "ratio": sim_best / calib_best, "fingerprint": fp}


def measure_best():
    """Minimum ratio over several passes, plus the observed noise spread.

    Timing noise on a shared host only ever inflates a measurement, so
    the minimum is the best estimator of the true cost — and a real
    regression shifts the whole distribution, minimum included.  The
    spread (max/min - 1 across passes, capped at 10%) is reported so the
    gate can widen its tolerance by the noise it actually observed: on a
    quiet machine the gate is a true 3% gate, on a contended one it does
    not fail spuriously."""
    runs = [measure() for _ in range(PASSES)]
    ratios = sorted(m["ratio"] for m in runs)
    best = min(runs, key=lambda m: m["ratio"])
    best["noise"] = min(ratios[-1] / ratios[0] - 1.0, 0.10)
    return best


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true",
                    help="re-pin the baseline JSON from this machine")
    args = ap.parse_args(argv)
    tol = float(os.environ.get("OBS_GATE_TOL", "0.03"))

    m = measure_best()
    fleet = fleet_fingerprint()
    chaos = chaos_fingerprint()
    chaos_topo = chaos_topology_fingerprint()
    if args.update:
        with open(BASELINE, "w") as f:
            json.dump(
                {
                    "workload": {"kind": "azure2021", "n_fns": N_FNS,
                                 "duration_s": DUR_S, "n_cores": N_CORES,
                                 "seed": SEED, "policy": "lags"},
                    "ratio": m["ratio"],
                    "fingerprint": m["fingerprint"],
                    "fleet": {
                        "n_nodes": FLEET_NODES,
                        "duration_s": FLEET_DUR_S,
                        "placements": fleet,
                    },
                    "chaos": chaos,
                    "chaos_topology": chaos_topo,
                },
                f, indent=2,
            )
            f.write("\n")
        print(f"obs_gate: baseline updated (ratio={m['ratio']:.3f}, "
              f"fingerprint={m['fingerprint']}, "
              f"fleet placements={sorted(fleet)})")
        return 0

    try:
        with open(BASELINE) as f:
            base = json.load(f)
    except FileNotFoundError:
        print(f"obs_gate: no baseline at {BASELINE}; run with --update",
              file=sys.stderr)
        return 2

    if m["fingerprint"] != base["fingerprint"]:
        print(
            "obs_gate: BEHAVIOR CHANGED — the seeded density-9 run no "
            f"longer matches the pinned fingerprint\n"
            f"  pinned:   {base['fingerprint']}\n"
            f"  measured: {m['fingerprint']}\n"
            "If intended, re-pin with: python scripts/obs_gate.py --update",
            file=sys.stderr,
        )
        return 1

    base_fleet = base.get("fleet", {}).get("placements")
    if base_fleet is None:
        print("obs_gate: baseline has no fleet fingerprint; re-pin with "
              "--update", file=sys.stderr)
        return 2
    if fleet != base_fleet:
        drift = [p for p in sorted(set(fleet) | set(base_fleet))
                 if fleet.get(p) != base_fleet.get(p)]
        print(
            "obs_gate: FLEET BEHAVIOR CHANGED — the 3-node density-9 "
            f"sweep no longer matches the pinned fingerprint "
            f"(placements drifted: {drift})\n"
            f"  pinned:   { {p: base_fleet.get(p) for p in drift} }\n"
            f"  measured: { {p: fleet.get(p) for p in drift} }\n"
            "If intended, re-pin with: python scripts/obs_gate.py --update",
            file=sys.stderr,
        )
        return 1

    base_chaos = base.get("chaos")
    if base_chaos is None:
        print("obs_gate: baseline has no chaos fingerprint; re-pin with "
              "--update", file=sys.stderr)
        return 2
    if chaos != base_chaos:
        drift = [k for k in sorted(set(chaos) | set(base_chaos))
                 if chaos.get(k) != base_chaos.get(k)]
        print(
            "obs_gate: FAILOVER BEHAVIOR CHANGED — the scripted 2-node "
            f"crash run no longer matches the pinned fingerprint "
            f"(drifted: {drift})\n"
            f"  pinned:   { {k: base_chaos.get(k) for k in drift} }\n"
            f"  measured: { {k: chaos.get(k) for k in drift} }\n"
            "If intended, re-pin with: python scripts/obs_gate.py --update",
            file=sys.stderr,
        )
        return 1

    base_topo = base.get("chaos_topology")
    if base_topo is None:
        print("obs_gate: baseline has no chaos_topology fingerprint; "
              "re-pin with --update", file=sys.stderr)
        return 2
    if chaos_topo != base_topo:
        drift = [k for k in sorted(set(chaos_topo) | set(base_topo))
                 if chaos_topo.get(k) != base_topo.get(k)]
        print(
            "obs_gate: TOPOLOGY-CHAOS BEHAVIOR CHANGED — the scripted "
            f"partition + rack-crash run no longer matches the pinned "
            f"fingerprint (drifted: {drift})\n"
            f"  pinned:   { {k: base_topo.get(k) for k in drift} }\n"
            f"  measured: { {k: chaos_topo.get(k) for k in drift} }\n"
            "If intended, re-pin with: python scripts/obs_gate.py --update",
            file=sys.stderr,
        )
        return 1

    slack = m["ratio"] / base["ratio"] - 1.0
    budget = tol + m["noise"]
    if slack > budget:
        # one retry before declaring a regression: a transient noisy-host
        # pass should not fail the gate
        m = measure_best()
        slack = min(slack, m["ratio"] / base["ratio"] - 1.0)
        budget = tol + m["noise"]
    status = "OK" if slack <= budget else "REGRESSION"
    print(
        f"obs_gate: {status} sim={m['sim_s']*1e3:.0f}ms "
        f"calib={m['calib_s']*1e3:.0f}ms ratio={m['ratio']:.3f} "
        f"baseline={base['ratio']:.3f} delta={slack*100:+.1f}% "
        f"(tol {tol*100:.0f}% + noise {m['noise']*100:.1f}%) "
        f"fleet={len(fleet)} placements OK, failover fingerprint OK, "
        f"topology-chaos fingerprint OK"
    )
    if slack > budget:
        print(
            "obs_gate: the telemetry-disabled hot path got slower — the "
            "obs layer must stay free when off (ROADMAP). If the change "
            "is intended, re-pin with --update.",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
