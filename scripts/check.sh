#!/usr/bin/env bash
# Tier-1 gate: full test suite + a short end-to-end observability smoke run.
#
#   scripts/check.sh            # from the repo root
#
# The smoke run drives launch/serve.py for 2 simulated seconds with tracing
# enabled, then renders the run record with the report CLI — exercising the
# whole obs path (metrics registry, schedstats, tracer, recorder, report).
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH=src

echo "== tier-1 test suite =="
python -m pytest -q

echo
echo "== obs-off regression gate: density-9 simkernel, telemetry disabled =="
python scripts/obs_gate.py

echo
echo "== obs smoke: 2 s serve run with tracing =="
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT
python -m repro.launch.serve --policy lags --tenants 8 --duration 2 \
    --obs-dir "$tmp/lags" --trace
python -m repro.obs.report "$tmp/lags"
python - "$tmp/lags/trace.json" <<'PY'
import json, sys
obj = json.load(open(sys.argv[1]))
assert obj["traceEvents"], "empty trace"
print(f"trace OK: {len(obj['traceEvents'])} events")
PY

echo
echo "check.sh: all good"
