#!/usr/bin/env bash
# Tier-1 gate: full test suite + a short end-to-end observability smoke run.
#
#   scripts/check.sh            # from the repo root
#
# The smoke run drives launch/serve.py for 2 simulated seconds with tracing
# and a live schedstats checkpoint enabled, then renders the run record with
# the report CLI — exercising the whole obs path (metrics registry,
# schedstats, tracer, recorder, report, checkpoint stream).  A second smoke
# runs a 2-node fleet and merges the per-node run records into one fleet
# view (`report --merge`).
#
# In CI (CI env var set) the dev extras are installed first so the property
# tests run under the *real* hypothesis engine with its shrinker; locally —
# and in the network-less container — the tests/conftest.py mini-engine is
# the fallback.
set -euo pipefail

cd "$(dirname "$0")/.."
export PYTHONPATH=src

if [ -n "${CI:-}" ] && ! python -c "import hypothesis" 2>/dev/null; then
    echo "== CI: installing dev extras (real hypothesis engine) =="
    pip install -r requirements-dev.txt
fi

echo "== tier-1 test suite =="
python -m pytest -q

echo
echo "== obs-off regression gate: density-9 simkernel + 3-node fleet =="
python scripts/obs_gate.py

echo
echo "== obs smoke: 2 s serve run with tracing + checkpoint stream =="
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT
python -m repro.launch.serve --policy lags --tenants 8 --duration 2 \
    --obs-dir "$tmp/lags" --trace --checkpoint-every 1
python -m repro.obs.report "$tmp/lags"
python - "$tmp/lags/trace.json" <<'PY'
import json, sys
obj = json.load(open(sys.argv[1]))
assert obj["traceEvents"], "empty trace"
print(f"trace OK: {len(obj['traceEvents'])} events")
PY

echo
echo "== fleet smoke: 2-node fleet, merged report =="
python - "$tmp/fleet" <<'PY'
import sys
from repro.fleet import make_policy, place, simulate_fleet
asg = place("spread", 20, 2, policy=make_policy("lags"))
fleet = simulate_fleet("lags", asg, duration_s=5.0, record_dir=sys.argv[1])
print(f"fleet OK: {fleet.n_nodes} nodes, {fleet.n_completed} completed, "
      f"p95={fleet.pct(95):.3f}s")
PY
python -m repro.obs.report --merge "$tmp/fleet/node0" "$tmp/fleet/node1"

echo
echo "== chaos smoke: 2-node fleet, scripted mid-run crash, failover report =="
python - "$tmp/chaos" <<'PY'
import sys
from repro.fleet import FaultSchedule, place, simulate_fleet_chaos
asg = place("spread", 24, 2, exec_s=0.1)
res = simulate_fleet_chaos(
    "lags", asg, FaultSchedule.single_crash(1, 3.0, 2),
    duration_s=9.0, epoch_s=1.5, exec_s=0.1, seed=10,
    record_dir=sys.argv[1],
)
assert res.per_epoch_counts()[-1][1] == 0, "crashed node not drained"
assert res.recovery_s()[1] is not None, "fleet never recovered"
print(f"chaos OK: {len(res.migrations)} migrations, "
      f"{res.n_completed} completed, recovery_s={res.recovery_s()[1]}")
PY
merged="$(python -m repro.obs.report --merge "$tmp/chaos" \
    "$tmp/chaos/node0" "$tmp/chaos/node1")"
echo "$merged"
case "$merged" in
  *failover:*) ;;
  *) echo "chaos smoke: merged report is missing the failover section" >&2
     exit 1 ;;
esac

echo
echo "== topology smoke: partition fencing + proactive drain of a trending node =="
python - <<'PY'
from repro.fleet import (
    FaultEvent, FaultSchedule, Topology, place, simulate_fleet_chaos,
)
topo = Topology.uniform(4, 2)
# node 2 trends degraded (below the reactive watchdog's min_ratio) while
# node 0 briefly partitions: the drainer must evacuate node 2 early and
# the fence must defer (not lose) node 0's arrivals
sched = FaultSchedule(
    [FaultEvent(1.5, "node_slow", 2, factor=1.8),
     FaultEvent(3.0, "partition", nodes=(0,), duration=4.5)],
    4, topo,
)
asg = place("rack-spread", 64, 4, exec_s=0.1, racks=topo.racks())
res = simulate_fleet_chaos(
    "lags", asg, sched, duration_s=12.0, epoch_s=1.5, exec_s=0.1, seed=10,
    topology=topo, proactive_drain=True, drain_enter_ratio=1.35,
    drain_exit_ratio=1.15,
)
drained = {n for e in res.epochs for n in e.draining}
fenced = {n for e in res.epochs for n in e.fenced}
assert 2 in drained, f"trending node never drained (drained={drained})"
assert any(m.src == 2 for m in res.migrations), "no drain migration"
assert fenced == {0}, f"partitioned node not fenced (fenced={fenced})"
assert res.lost_arrivals == 0, "fenced arrivals were lost, not deferred"
assert res.deferred_arrivals > 0 and res.replayed_arrivals >= res.deferred_arrivals
assert all(sum(e.counts) == 64 for e in res.epochs), "conservation broken"
print(f"topology OK: drained={sorted(drained)} fenced={sorted(fenced)} "
      f"deferred={res.deferred_arrivals} replayed={res.replayed_arrivals} "
      f"migrations={len(res.migrations)} done={res.done_ratio*100:.1f}%")
PY

echo
echo "check.sh: all good"
