"""Per-architecture smoke tests: reduced config, one forward/train step on CPU.

Asserts output shapes and absence of NaNs for every assigned architecture
family, plus prefill->decode consistency for decoder archs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs, reduced
from repro.models import model

ARCHS = [
    "jamba-v0.1-52b",
    "qwen3-8b",
    "stablelm-1.6b",
    "mistral-nemo-12b",
    "gemma3-27b",
    "qwen2-moe-a2.7b",
    "qwen3-moe-235b-a22b",
    "qwen2-vl-7b",
    "falcon-mamba-7b",
    "hubert-xlarge",
]

B, S = 2, 32


def make_batch(cfg, rng):
    r1, r2, r3 = jax.random.split(rng, 3)
    if cfg.frontend == "audio_frames":
        batch = {
            "frames": jax.random.normal(r1, (B, S, cfg.d_model), jnp.float32),
            "targets": jax.random.randint(r2, (B, S), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }
    else:
        batch = {
            "tokens": jax.random.randint(r1, (B, S), 0, cfg.vocab_size),
            "targets": jax.random.randint(r2, (B, S), 0, cfg.vocab_size),
            "loss_mask": jnp.ones((B, S), jnp.float32),
        }
    if cfg.frontend == "vision":
        batch["vision_embeds"] = jax.random.normal(
            r3, (B, cfg.n_vision_tokens, cfg.d_model), jnp.float32
        )
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        batch["positions"] = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    rng = jax.random.PRNGKey(0)
    params = model.init_params(cfg, rng)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p_: model.train_loss(p_, cfg, b), has_aux=True
        )(p)
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree_util.tree_leaves(grads))
        )
        return loss, gnorm

    loss, gnorm = step(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss NaN/inf"
    assert np.isfinite(float(gnorm)), f"{arch}: grad NaN/inf"
    # random init -> loss near log(V)
    assert 0.0 < float(loss) < 3.0 * np.log(cfg.vocab_size) + 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    if cfg.encoder_only:
        # encoder-only: prefill = full forward, no decode
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        logits, cache = model.prefill(params, cfg, batch)
        assert logits.shape == (B, cfg.vocab_size)
        assert cache is None
        assert np.all(np.isfinite(np.asarray(logits, np.float32)))
        return
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    max_len = S + 4
    logits, cache = model.prefill(params, cfg, batch, max_len=max_len)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    tok = jnp.argmax(logits, -1)[:, None]
    dbatch = {"tokens": tok}
    if cfg.frontend == "vision":
        pos = jnp.full((B, 1, 3), S, jnp.int32)
        dbatch["positions"] = pos
    logits2, cache = model.decode_step(
        params, cfg, dbatch, cache, jnp.asarray(S, jnp.int32)
    )
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_decode_matches_prefill():
    """Teacher-forced decode over a prompt must match prefill logits."""
    cfg = reduced(get_config("qwen3-8b"))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_p, _ = model.prefill(params, cfg, {"tokens": toks}, max_len=S + 4)

    cache = model.init_cache(cfg, B, S + 4)
    logits_d = None
    for t in range(S):
        logits_d, cache = model.decode_step(
            params, cfg, {"tokens": toks[:, t : t + 1]}, cache,
            jnp.asarray(t, jnp.int32),
        )
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(logits_d, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_decode_matches_prefill_ssm():
    cfg = reduced(get_config("falcon-mamba-7b"), n_layers=2)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    logits_p, _ = model.prefill(params, cfg, {"tokens": toks}, max_len=S + 4)

    cache = model.init_cache(cfg, B, S + 4)
    logits_d = None
    for t in range(S):
        logits_d, cache = model.decode_step(
            params, cfg, {"tokens": toks[:, t : t + 1]}, cache,
            jnp.asarray(t, jnp.int32),
        )
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(logits_d, np.float32),
        rtol=2e-3, atol=2e-3,
    )


def test_all_configs_registered():
    assert set(ARCHS) <= set(list_configs())
