"""Fleet layer: placement conservation, differential contracts, merged obs.

The load-bearing guarantees pinned here:

  * every placement strategy conserves the function count (the legacy
    ``simulate_node_share`` floor silently dropped up to ``n_nodes - 1``
    functions — the (800, 14) case is the regression that motivated the
    fleet layer);
  * a placement handing every node identical per-node shares reproduces
    the legacy representative-node numbers *exactly* (numpy and JAX);
  * the vmapped+padded JAX fleet path is bit-identical to per-node
    unpadded scans, and statistically agrees with the numpy tick engine;
  * fleet observability: ``SchedStats.merge`` totals add up, and
    ``repro.obs.report --merge`` renders one view from per-node records.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cluster import simulate_node_share
from repro.fleet import (
    PLACEMENTS,
    consolidation_sweep,
    fn_shares,
    make_policy,
    min_nodes_meeting_slo,
    place,
    placement_comparison,
    record_fleet,
    simulate_fleet,
    switch_penalty,
)
from repro.obs.schedstats import SchedStats, from_sim_result


# -- placement: conservation ------------------------------------------------

@pytest.mark.parametrize("name", sorted(PLACEMENTS))
def test_placement_conserves_function_count(name):
    asg = place(name, 23, 4, policy=make_policy("lags"))
    assert int(asg.counts.sum()) == 23
    seen = np.concatenate(asg.node_fns)
    assert len(np.unique(seen)) == 23


@pytest.mark.parametrize("name", sorted(PLACEMENTS))
def test_800_over_14_regression(name):
    """The legacy floor gave 14 * (800 // 14) = 798 functions; placements
    must assign all 800."""
    asg = place(name, 800, 14, policy=make_policy("lags"))
    assert int(asg.counts.sum()) == 800  # not 798
    # and the legacy path indeed drops them (documented approximation)
    assert 14 * max(1, 800 // 14) == 798


@settings(max_examples=15)
@given(
    total=st.integers(min_value=1, max_value=64),
    n_nodes=st.integers(min_value=1, max_value=7),
    name=st.sampled_from(sorted(PLACEMENTS)),
)
def test_placement_conservation_property(total, n_nodes, name):
    asg = place(name, total, n_nodes, policy=make_policy("cfs"))
    assert int(asg.counts.sum()) == total
    assert len(np.unique(np.concatenate(asg.node_fns))) == total


def test_unknown_placement_rejected():
    with pytest.raises(ValueError, match="unknown placement"):
        place("best-fit-ever", 10, 2)


def test_switch_aware_stacks_less_than_pack():
    """pack is the consolidation-friendly extreme; switch-aware trades some
    density away against the policy's voluntary-switch cost."""
    shares = fn_shares(120, seed=7)
    packed = place("pack", 120, 4, shares=shares)
    aware = place("switch-aware", 120, 4, shares=shares,
                  policy=make_policy("cfs"))
    assert aware.counts.max() < packed.counts.max()
    assert aware.share_imbalance() <= packed.share_imbalance() + 1e-9


def test_switch_penalty_monotone_and_policy_aware():
    """Denser cgroup stacking costs more, and CFS pays more than LAGS
    (run-to-completion handoffs are near-free) — the signal switch-aware
    placement keys on."""
    cfs, lags = make_policy("cfs"), make_policy("lags")
    sparse = switch_penalty(cfs, 8, util=0.8)
    dense = switch_penalty(cfs, 96, util=0.8)
    assert 0.0 <= sparse < dense < 1.0
    assert switch_penalty(lags, 96, util=0.8) < dense
    assert switch_penalty(cfs, 0, util=0.8) == 0.0


# -- differential: fleet vs legacy representative node ----------------------

def test_round_robin_fleet_matches_legacy_exactly():
    """Equal-count round-robin nodes regenerate the same band workload the
    legacy single-node path simulated: per-node results are identical."""
    legacy = simulate_node_share("lags", 24, 2, duration_s=8.0)
    asg = place("round-robin", 24, 2)
    fleet = simulate_fleet("lags", asg, duration_s=8.0)
    assert list(asg.counts) == [12, 12]
    for r in fleet.nodes:
        np.testing.assert_array_equal(r.latencies, legacy.latencies)
        assert r.switches == legacy.switches
        assert r.busy_time_s == legacy.busy_time_s
        assert r.switch_time_s == legacy.switch_time_s
    assert fleet.n_completed == 2 * legacy.n_completed


def test_pack_with_uniform_shares_matches_legacy():
    """Uniform shares + headroom=1.0 force pack into an even split, which
    must then reproduce the legacy numbers too (placement only acts through
    the per-node counts under the shared-seed band model)."""
    shares = np.full(24, 1.0 / 64.0)
    asg = place("pack", 24, 2, shares=shares, headroom=1.0)
    assert list(asg.counts) == [12, 12]
    fleet = simulate_fleet("cfs", asg, duration_s=8.0)
    legacy = simulate_node_share("cfs", 24, 2, duration_s=8.0)
    for r in fleet.nodes:
        np.testing.assert_array_equal(r.latencies, legacy.latencies)
        assert r.busy_time_s == legacy.busy_time_s


def test_equal_count_nodes_share_one_simulation():
    """Shared seed + equal counts -> the numpy path simulates once and
    reuses the result object (the banded-placement fast path)."""
    asg = place("round-robin", 36, 3)
    fleet = simulate_fleet("lags", asg, duration_s=5.0)
    assert fleet.nodes[0] is fleet.nodes[1] is fleet.nodes[2]
    distinct = simulate_fleet("lags", asg, duration_s=5.0,
                              distinct_seeds=True)
    assert distinct.nodes[0] is not distinct.nodes[1]
    assert not np.array_equal(distinct.nodes[0].latencies,
                              distinct.nodes[1].latencies)


def test_pack_idle_nodes_are_empty_results():
    """pack may drain tail nodes entirely; they must appear as explicit
    zero-work nodes, not crash the workload synthesiser."""
    shares = np.full(8, 0.05)
    asg = place("pack", 8, 4, shares=shares, headroom=4.0)
    assert 0 in asg.counts
    fleet = simulate_fleet("lags", asg, duration_s=4.0)
    assert fleet.n_nodes == 4
    for r, k in zip(fleet.nodes, asg.counts):
        if k == 0:
            assert r.n_arrived == 0 and r.busy_time_s == 0.0
    assert fleet.n_arrived == sum(
        r.n_arrived for r, k in zip(fleet.nodes, asg.counts) if k > 0
    )


# -- differential: vmapped JAX fleet ---------------------------------------

def test_jax_fleet_matches_per_node_scan_exactly():
    """Padding to the common (T, R) and vmapping must be bit-identical to
    running each node's unpadded scan alone."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core import simkernel_jax as sj
    from repro.core.traces import make_workload
    from repro.sched.jax_backend import CODE_OF

    asg = place("round-robin", 11, 2)  # counts (6, 5): uneven, forces padding
    fleet = simulate_fleet("lags", asg, duration_s=5.0, backend="jax")
    assert list(asg.counts) == [6, 5]
    for node, k in zip(fleet.nodes, asg.counts):
        wl = make_workload("azure2021", int(k), duration_s=5.0, n_cores=12,
                           seed=7, exec_s=0.2, threads_per_fn=8)
        trace = sj.build_slot_trace(wl, int(k), 8)
        p = sj.SimParams(n_cores=12, n_fns=int(k),
                         n_ticks=int(5.0 / sj.TICK),
                         policy=CODE_OF["lags"], burst_us=280.0, depth=5.0)
        out = sj.simulate(trace, p)
        lat = sj.latencies_from(trace, out["done_tick"])
        np.testing.assert_array_equal(np.sort(node.latencies), np.sort(lat))
        assert abs(node.busy_time_s - float(out["busy_s"])) < 1e-6
        assert abs(node.switch_time_s - float(out["overhead_s"])) < 1e-6


def test_jax_fleet_agrees_with_numpy_fleet():
    """Backend-differential (same tolerances as test_simkernel_jax): the
    scan fleet and the tick fleet see the same cluster."""
    pytest.importorskip("jax")
    asg = place("round-robin", 20, 2)
    ref = simulate_fleet("lags", asg, duration_s=10.0, threads_per_fn=8)
    jx = simulate_fleet("lags", asg, duration_s=10.0, backend="jax")
    assert abs(jx.n_completed - ref.n_completed) <= max(
        6, 0.05 * ref.n_completed)
    assert abs(jx.pct(50) - ref.pct(50)) < 0.25 * max(ref.pct(50), 0.05)
    assert abs(jx.overhead_frac - ref.overhead_frac) < 0.05


# -- consolidation search ---------------------------------------------------

def test_consolidation_sweep_reports_imbalance_fields():
    res = consolidation_sweep(
        total_fns=24, node_counts=(3, 2), policies=("lags",),
        duration_s=5.0,
    )
    assert len(res) == 2
    for r in res:
        assert r.placement == "round-robin"
        assert r.p95_spread >= 0.0
        assert r.ovh_max_over_mean >= 1.0 - 1e-9
    n = min_nodes_meeting_slo(res, "lags")
    assert n in (2, 3)


def test_placement_comparison_runs_all_strategies(tmp_path):
    res = placement_comparison(
        24, 2, policy="lags", duration_s=4.0,
        placements=("round-robin", "pack"),
        record_dir=str(tmp_path),
    )
    assert [r.placement for r in res] == ["round-robin", "pack"]
    assert (tmp_path / "round-robin" / "node0" / "run.json").exists()
    assert (tmp_path / "pack" / "node1" / "run.json").exists()


# -- fleet observability ----------------------------------------------------

def test_schedstats_merge_sums_totals_and_entities():
    a, b = SchedStats("node0"), SchedStats("node1")
    for stx, ent in ((a, 1), (b, 2)):
        stx.account_time(10.0)
        stx.account_useful(ent, 4.0)
        stx.account_switch(ent, 0.5, n=5)
        stx.account_completion(ent, 0.2)
        stx.account_completion(1, 0.4)
    m = SchedStats.merged([a, b], name="fleet")
    assert m.time_s == 20.0
    assert m.useful_s == 8.0
    assert m.switch_s == 1.0
    assert m.switches == 10
    assert m.latency.count == 4
    assert m.entities[1].completed == 3  # 2 from a, 1 from b
    assert m.entities[2].completed == 1
    assert m.entities[1].switches == 5
    # merge is additive on histograms, not averaging
    assert m.switch_cost_us.count == a.switch_cost_us.count * 2


def test_fleet_merged_sched_matches_sum_of_nodes():
    asg = place("round-robin", 18, 2)
    fleet = simulate_fleet("lags", asg, duration_s=5.0,
                           distinct_seeds=True)
    merged = fleet.merged_sched()
    assert merged.useful_s == pytest.approx(
        sum(r.busy_time_s for r in fleet.nodes))
    assert merged.switches == sum(r.switches for r in fleet.nodes)
    assert merged.latency.count == fleet.n_completed
    ref = SchedStats.merged([from_sim_result(r) for r in fleet.nodes])
    assert merged.switch_share == pytest.approx(ref.switch_share)


def test_report_merge_renders_fleet_view(tmp_path):
    from repro.obs import report

    asg = place("round-robin", 18, 2)
    fleet = simulate_fleet("lags", asg, duration_s=5.0,
                           distinct_seeds=True)
    paths = record_fleet(fleet, str(tmp_path))
    assert len(paths) == 2
    text = report.main(["--merge", str(tmp_path / "node0"),
                        str(tmp_path / "node1")])
    assert "fleet view: 2 run records merged" in text
    assert "policies: lags" in text
    assert "per-shard:" in text and "merged:" in text
    # merged completion count = fleet total
    assert f"{fleet.n_completed}" in text


def test_report_merge_requires_two_runs(tmp_path):
    from repro.obs import report

    with pytest.raises(SystemExit):
        report.main(["--merge", str(tmp_path)])


def test_imbalance_report_fields():
    asg = place("pack", 40, 3, policy=make_policy("lags"))
    fleet = simulate_fleet("lags", asg, duration_s=5.0)
    imb = fleet.imbalance()
    assert set(imb) == {"p95_min", "p95_max", "p95_spread",
                        "ovh_max_over_mean"}
    assert imb["p95_max"] >= imb["p95_min"]
    assert imb["p95_spread"] == pytest.approx(
        imb["p95_max"] - imb["p95_min"])


# -- live schedstats streaming ----------------------------------------------

def test_engine_run_fires_checkpoints():
    from repro.launch.serve import build_workload
    from repro.serving.engine import Engine, EngineConfig

    tenants, arrivals = build_workload(4, 2.0, seed=0)
    eng = Engine(EngineConfig(policy="lags", n_slots=4), tenants)
    snaps = []
    eng.run(2.0, arrivals, checkpoint_every_s=0.5,
            on_checkpoint=lambda stx: snaps.append(stx.time_s))
    assert len(snaps) >= 3
    assert snaps == sorted(snaps)
    # no checkpointing when the knob is off
    eng2 = Engine(EngineConfig(policy="lags", n_slots=4), tenants)
    missed = []
    eng2.run(1.0, arrivals, on_checkpoint=lambda stx: missed.append(1))
    assert missed == []


def test_serve_streams_checkpoints_and_shard_meta(tmp_path, capsys):
    from repro.launch import serve
    from repro.obs.recorder import load_run

    serve.main([
        "--policy", "lags", "--tenants", "4", "--duration", "2",
        "--obs-dir", str(tmp_path), "--checkpoint-every", "0.5",
        "--shard", "s0",
    ])
    run = load_run(str(tmp_path))
    assert run["meta"]["shard"] == "s0"
    assert run["meta"]["checkpoints"] >= 3
    assert "live" not in run["meta"]  # final record, not a checkpoint
    assert run["sched"] is not None
    assert "checkpoints=" in capsys.readouterr().out
