"""Dry-run machinery on a small in-process mesh: every cell's Lowerable can
be built and LOWERED (no compile — the 512-device compile sweep is the
background dry-run; this test pins the sharding spec construction)."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh

from repro.configs.base import SHAPES, get_config, list_configs
from repro.distributed.sharding import sharding_ctx
from repro.launch.specs import build_lowerable, cell_skip_reason

ARCHS = list_configs()


def _mesh11():
    devs = np.asarray(jax.devices()[:1]).reshape(1, 1)
    return Mesh(devs, ("data", "model"))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
def test_lowerable_builds(arch, shape):
    cfg = get_config(arch)
    if cell_skip_reason(cfg, shape):
        pytest.skip(cell_skip_reason(cfg, shape))
    mesh = _mesh11()
    low = build_lowerable(cfg, SHAPES[shape], mesh)
    # arg specs and shardings are structurally consistent
    flat_args = jax.tree_util.tree_leaves(low.args_sds)
    assert all(hasattr(a, "shape") for a in flat_args)
    ins = jax.tree_util.tree_structure(low.in_shardings)
    del ins


def test_skip_matrix_matches_design():
    """9 rule-skips: long_500k for 8 full-attention archs (incl. encoder),
    decode_32k for the encoder-only arch."""
    skips = [
        (a, s)
        for a in ARCHS
        for s in SHAPES
        if cell_skip_reason(get_config(a), s)
    ]
    long_skips = {a for a, s in skips if s == "long_500k"}
    decode_skips = {a for a, s in skips if s == "decode_32k"}
    assert long_skips == set(ARCHS) - {"jamba-v0.1-52b", "falcon-mamba-7b"}
    assert decode_skips == {"hubert-xlarge"}
    assert len(skips) == 9


def test_production_mesh_shapes():
    # shape arithmetic only (device count on CPU is 1; the real meshes are
    # exercised by the dry-run sweep under XLA_FLAGS=512)
    from repro.launch import mesh as mesh_lib

    assert mesh_lib.make_production_mesh.__kwdefaults__ == {"multi_pod": False}
