"""Sort-based EP dispatch vs the dense capacity-dispatch semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


def _dense_oracle(x, ids, gate, w_gate, w_up, w_down, capacity):
    """Straightforward per-(token,k) loop with per-expert capacity."""
    T, K = ids.shape
    E = w_gate.shape[0]
    used = np.zeros(E, int)
    y = np.zeros_like(np.asarray(x))
    total_cap = capacity  # single peer: shared buffer across experts
    placed = 0
    for t in range(T):
        for k in range(K):
            e = int(ids[t, k])
            if placed >= total_cap:
                continue
            placed += 1
            xe = np.asarray(x[t])
            g = xe @ np.asarray(w_gate[e])
            u = xe @ np.asarray(w_up[e])
            h = (g / (1 + np.exp(-g))) * u
            y[t] += float(gate[t, k]) * (h @ np.asarray(w_down[e]))
    return y


def test_local_matches_oracle():
    from repro.distributed.ep_a2a import moe_ep_a2a_local

    rng = np.random.default_rng(0)
    T, K, E, M, F = 16, 2, 4, 8, 16
    x = jnp.asarray(rng.standard_normal((T, M)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, E, (T, K)))
    gate = jnp.asarray(rng.uniform(0.1, 1.0, (T, K)), jnp.float32)
    w_gate = jnp.asarray(rng.standard_normal((E, M, F)) * 0.1, jnp.float32)
    w_up = jnp.asarray(rng.standard_normal((E, M, F)) * 0.1, jnp.float32)
    w_down = jnp.asarray(rng.standard_normal((E, F, M)) * 0.1, jnp.float32)

    cap = T * K  # no drops
    y = moe_ep_a2a_local(x, ids, gate, w_gate, w_up, w_down,
                         capacity_factor=float(cap) / (T * K))
    want = _dense_oracle(x, ids, gate, w_gate, w_up, w_down, cap)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-4, atol=1e-4)


def test_capacity_drops_are_bounded():
    from repro.distributed.ep_a2a import moe_ep_a2a_local

    rng = np.random.default_rng(1)
    T, K, E, M, F = 32, 2, 4, 8, 16
    x = jnp.asarray(rng.standard_normal((T, M)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, E, (T, K)))
    gate = jnp.ones((T, K), jnp.float32)
    w = lambda *s: jnp.asarray(rng.standard_normal(s) * 0.1, jnp.float32)
    y_full = moe_ep_a2a_local(x, ids, gate, w(E, M, F), w(E, M, F),
                              w(E, F, M), capacity_factor=1.0)
    assert np.isfinite(np.asarray(y_full)).all()


def test_shard_map_single_device():
    """all_to_all path under shard_map on a 1-device 'model' axis equals the
    local path (exercises the collective wiring)."""
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map
    from repro.distributed.ep_a2a import moe_ep_a2a_local

    rng = np.random.default_rng(2)
    T, K, E, M, F = 8, 2, 4, 8, 8
    x = jnp.asarray(rng.standard_normal((T, M)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, E, (T, K)))
    gate = jnp.asarray(rng.uniform(0.1, 1.0, (T, K)), jnp.float32)
    w = lambda *s: jnp.asarray(rng.standard_normal(s) * 0.1, jnp.float32)
    wg, wu, wd = w(E, M, F), w(E, M, F), w(E, F, M)

    mesh = Mesh(np.asarray(jax.devices()[:1]), ("model",))
    fn = shard_map(
        lambda *a: moe_ep_a2a_local(*a, axis_name="model", capacity_factor=2.0),
        mesh=mesh,
        in_specs=(P(), P(), P(), P(), P(), P()),
        out_specs=P(),
        check_vma=False,
    )
    y_sm = fn(x, ids, gate, wg, wu, wd)
    y_local = moe_ep_a2a_local(x, ids, gate, wg, wu, wd, capacity_factor=2.0)
    np.testing.assert_allclose(np.asarray(y_sm), np.asarray(y_local),
                               rtol=1e-5, atol=1e-5)
