"""Event-driven oracle: exact scheduling-order semantics per policy."""
import numpy as np

from repro.core.des import EventSim
from repro.core.policies import make_policy


def test_single_request_exact_latency():
    sim = EventSim(n_fns=1, n_cores=1, policy=make_policy("cfs"))
    sim.submit(0, t=0.0, demand=0.25)
    lat = sim.run(until=2.0)
    np.testing.assert_allclose(lat, [0.25], atol=1e-9)


def test_two_requests_share_one_core():
    """CFS processor sharing: two equal jobs finish ~together at 2x."""
    sim = EventSim(n_fns=2, n_cores=1, policy=make_policy("cfs"))
    sim.submit(0, 0.0, 0.2)
    sim.submit(1, 0.0, 0.2)
    lat = sim.run(until=5.0)
    assert len(lat) == 2
    assert all(l > 0.3 for l in lat)  # both ~0.4 under PS


def test_lags_runs_lightest_to_completion():
    """Under LAGS the fresh (zero-credit) function preempts and finishes at
    its service time; the heavy function is delayed."""
    pol = make_policy("lags")
    sim = EventSim(n_fns=2, n_cores=1, policy=pol)
    # make fn 0 heavy: accumulated credit
    sim.tracker.credit[:] = [1.0, 0.0]
    sim.submit(0, 0.0, 0.3)
    sim.submit(1, 0.01, 0.1)
    lat = sim.run(until=5.0)
    lat_light = lat[1] if len(lat) == 2 else min(lat)
    assert lat_light < 0.13  # ran to completion immediately


def test_work_conserving_multicore():
    sim = EventSim(n_fns=3, n_cores=3, policy=make_policy("cfs"))
    for f in range(3):
        sim.submit(f, 0.0, 0.2)
    lat = sim.run(until=1.0)
    np.testing.assert_allclose(lat, [0.2] * 3, atol=0.02)


def test_des_vs_simkernel_direction():
    """Oracle and tick engine agree on PS sharing within tick tolerance."""
    from repro.core.simkernel import SimConfig, Workload, simulate

    arr = [np.asarray([0.0]), np.asarray([0.0])]
    svc = [np.asarray([0.2]), np.asarray([0.2])]
    wl = Workload(2, arr, svc, threads_per_fn=1, duration_s=2.0)
    r = simulate(wl, make_policy("cfs"),
                 SimConfig(n_cores=1, model_switch_cost=False))
    sim = EventSim(2, 1, make_policy("cfs"))
    sim.submit(0, 0.0, 0.2)
    sim.submit(1, 0.0, 0.2)
    lat_des = sim.run(until=2.0)
    assert abs(np.max(r.latencies) - np.max(lat_des)) < 0.05
