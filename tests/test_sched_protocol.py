"""Unified policy protocol: registry, keys, hysteresis, invariants."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import load_credit as lc
from repro.sched import numpy_backend as nb
from repro.sched import protocol


# -- registry ---------------------------------------------------------------

def test_registry_has_all_policies():
    names = protocol.names()
    for n in ("cfs", "cfs-tuned", "eevdf", "eevdf-tuned", "rr", "lags",
              "lags-static"):
        assert n in names
    assert {protocol.spec(n).kind for n in names} == set(protocol.KINDS)


def test_registry_lookup_and_overrides():
    s = protocol.spec("lags")
    assert s.kind == "lags" and s.preempt_hysteresis == 1.0
    s2 = protocol.spec("lags", preempt_hysteresis=0.25, credit_window=64)
    assert s2.preempt_hysteresis == 0.25 and s2.credit_window == 64
    # overrides never mutate the registered spec
    assert protocol.spec("lags").preempt_hysteresis == 1.0
    with pytest.raises(ValueError):
        protocol.spec("not-a-policy")
    with pytest.raises(ValueError):
        protocol.register(protocol.PolicySpec("bad", "not-a-kind"))


def test_make_policy_compat_surface():
    p = nb.make_policy("cfs-tuned")
    assert p.slice_ticks == protocol.TUNED_SLICE_TICKS
    assert not p.lags and not p.run_to_completion
    p = nb.make_policy("lags-static", static_rt_fns=[0, 3])
    assert p.run_to_completion
    assert list(p.static_rt_fns) == [0, 3]
    assert p.spec.static_rt_fns == (0, 3)


# -- hysteresis preemption rule --------------------------------------------

def test_credit_preempt_boundary():
    """The documented boundary: strictly below hysteresis*run fires,
    at the boundary (or above) it does not."""
    assert protocol.credit_preempt(0.49, 1.0, 0.5)
    assert not protocol.credit_preempt(0.5, 1.0, 0.5)  # exact boundary
    assert not protocol.credit_preempt(0.51, 1.0, 0.5)
    # node-simulator setting: any strictly lighter waiter fires
    assert protocol.credit_preempt(0.999999, 1.0, 1.0)
    assert not protocol.credit_preempt(1.0, 1.0, 1.0)  # equal -> no churn
    # float-noise guard: epsilon-equal credits do not fire
    assert not protocol.credit_preempt(1.0 - 1e-15, 1.0, 1.0)


@given(st.floats(0.0, 4.0), st.floats(0.0, 4.0), st.floats(0.1, 1.0))
@settings(max_examples=60, deadline=None)
def test_credit_preempt_monotone(wait, run, h):
    """If a waiter fires at hysteresis h, any lighter waiter also fires,
    and any higher hysteresis also fires."""
    if protocol.credit_preempt(wait, run, h):
        assert protocol.credit_preempt(wait * 0.5, run, h)
        assert protocol.credit_preempt(wait, run, min(1.0, h * 1.5))


# -- key monotonicity -------------------------------------------------------

def _view(credits, vrts, ent_group, last_pick=None):
    credits = np.asarray(credits, float)
    vrts = np.asarray(vrts, float)
    ent_group = np.asarray(ent_group, int)
    T = len(ent_group)
    return nb.EntityView(
        ent_group=ent_group,
        group_vrt=vrts,
        group_credit=credits,
        last_pick_tick=np.zeros(T) if last_pick is None
        else np.asarray(last_pick, float),
        runnable=np.ones(T, bool),
        group_runnable=np.ones(len(credits), bool),
        is_rt_group=np.zeros(len(credits), bool),
    )


@given(
    st.lists(st.floats(0.01, 4.0), min_size=2, max_size=8),
    st.integers(0, 7),
)
@settings(max_examples=40, deadline=None)
def test_lags_key_monotone_in_credit(credits, which):
    """Lowering a group's credit never worsens its entities' rank."""
    g = which % len(credits)
    ent_group = np.arange(len(credits))
    v = _view(credits, np.zeros(len(credits)), ent_group)
    before = nb.primary_key(protocol.spec("lags"), v)
    rank_before = int(np.sum(before < before[g]))
    lowered = list(credits)
    lowered[g] *= 0.5
    v2 = _view(lowered, np.zeros(len(credits)), ent_group)
    after = nb.primary_key(protocol.spec("lags"), v2)
    rank_after = int(np.sum(after < after[g]))
    assert rank_after <= rank_before


@given(st.lists(st.floats(0.0, 10.0), min_size=2, max_size=8))
@settings(max_examples=40, deadline=None)
def test_cfs_key_orders_by_vruntime(vrts):
    v = _view(np.zeros(len(vrts)), vrts, np.arange(len(vrts)))
    key = nb.primary_key(protocol.spec("cfs"), v)
    assert np.array_equal(np.argsort(key, kind="stable"),
                          np.argsort(np.asarray(vrts), kind="stable"))


def test_lags_static_rt_sorts_before_all_cfs():
    v = _view([1.0, 1.0, 1.0], [0.0, 5.0, 99.0], [0, 1, 2],
              last_pick=[7.0, 0.0, 3.0])
    v.is_rt_group[2] = True
    key = nb.primary_key(protocol.spec("lags-static"), v)
    assert key[2] < key[0] and key[2] < key[1]  # RT first, always
    assert key[0] < key[1]  # CFS part still vruntime-ordered


def test_eevdf_ineligible_sorts_last_but_keeps_tiebreak():
    """The ineligible offset must not quantize away the composite-key
    secondary (the regression that motivated EEVDF_INELIGIBLE=1e4)."""
    base = protocol.EEVDF_INELIGIBLE
    composite = base * 1e9 + 0.25
    assert composite != base * 1e9  # rank survives float64 addition
    v = _view(np.zeros(3), [0.0, 10.0, 0.1], np.arange(3))
    key = nb.primary_key(protocol.spec("eevdf"), v)
    assert np.argmax(key) == 1  # far-ahead vruntime is ineligible -> last


# -- credit-window invariants ----------------------------------------------

@given(
    st.sampled_from(["lags", "lags-static"]),
    st.lists(st.floats(0.0, 8.0), min_size=1, max_size=120),
)
@settings(max_examples=30, deadline=None)
def test_credit_window_invariants(name, fracs):
    """Credit driven through a spec's window stays within [0, max(frac)]
    and a shorter window reacts at least as fast (paper §4.2)."""
    spec = protocol.spec(name)
    fast = protocol.spec(name, credit_window=max(spec.credit_window // 8, 2))
    c_slow = c_fast = l_slow = l_fast = 0.0
    for f in fracs:
        l_slow = lc.pelt_update(l_slow, f)
        l_fast = lc.pelt_update(l_fast, f)
        c_slow = lc.ema_update(c_slow, l_slow, spec.credit_window)
        c_fast = lc.ema_update(c_fast, l_fast, fast.credit_window)
    bound = max(fracs) + 1e-9
    assert 0.0 <= c_slow <= bound and 0.0 <= c_fast <= bound
    if all(f == fracs[0] for f in fracs):
        # constant input: the short window is at least as converged
        assert abs(c_fast - l_fast) <= abs(c_slow - l_slow) + 1e-12
