"""Logical-axis resolution: divisibility, duplicate-axis handling."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

jax = pytest.importorskip("jax")
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.distributed import sharding as sh  # noqa: E402


def _mesh(shape=(2, 2), axes=("data", "model")):
    devs = np.asarray(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    from jax.sharding import Mesh

    return Mesh(devs, axes)


MESH = _mesh()


def test_divisible_kept():
    ps = sh.to_pspec(("batch", "heads"), rules=sh.TRAIN_RULES, mesh=MESH,
                     shape=(8, 4))
    assert ps == P("data", "model")


def test_nondivisible_dropped():
    ps = sh.to_pspec(("batch", "heads"), rules=sh.TRAIN_RULES, mesh=MESH,
                     shape=(3, 4))
    assert ps == P(None, "model")


def test_duplicate_axis_first_wins():
    # kv_seq and kv_heads both map to "model" in DECODE_RULES
    ps = sh.to_pspec(("batch", "kv_seq", "kv_heads", None),
                     rules=sh.DECODE_RULES, mesh=MESH, shape=(4, 8, 8, 16))
    assert ps == P("data", "model", None, None)


def test_tuple_axis_prefix_fallback():
    mesh3 = _mesh((2, 2, 1), ("pod", "data", "model"))
    # batch=2 divisible by pod(2) but not pod*data(4): falls back to ("pod",)
    ps = sh.to_pspec(("batch",), rules=sh.TRAIN_RULES, mesh=mesh3, shape=(2,))
    assert ps == P("pod")


def test_missing_mesh_axis_filtered():
    ps = sh.to_pspec(("batch",), rules=sh.TRAIN_RULES, mesh=MESH, shape=(8,))
    # ("pod","data") -> pod absent on 2-axis mesh -> data only
    assert ps == P("data")


@given(
    st.lists(
        st.sampled_from([None, "batch", "heads", "mlp", "vocab", "embed_p",
                         "experts", "kv_seq"]),
        min_size=1, max_size=5,
    ),
    st.lists(st.integers(1, 64), min_size=5, max_size=5),
)
@settings(max_examples=100, deadline=None)
def test_resolution_always_valid(logical, dims):
    """Property: resolved specs never violate divisibility or axis reuse."""
    shape = tuple(dims[: len(logical)])
    ps = sh.to_pspec(tuple(logical), rules=sh.DECODE_RULES, mesh=MESH,
                     shape=shape)
    sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
    used = []
    for dim, entry in zip(shape, tuple(ps)):
        if entry is None:
            continue
        axes = (entry,) if isinstance(entry, str) else entry
        prod = 1
        for a in axes:
            assert a not in used, "mesh axis used twice"
            used.append(a)
            prod *= sizes[a]
        assert dim % prod == 0, "non-divisible sharding emitted"


def test_constrain_noop_without_mesh():
    import jax.numpy as jnp

    x = jnp.ones((4, 4))
    assert sh.constrain(x, "batch", None) is x
