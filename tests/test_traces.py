"""Trace synthesis: demand bands, calibration, workload kinds."""
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import traces


def test_band_structure():
    rates = traces.band_rates()
    assert len(rates) == traces.N_BANDS
    assert (np.diff(rates) > 0).all()  # sorted ascending like Fig 2
    assert rates[-1] / rates[0] > 100  # heavy skew


def test_calibration_at_peak_density():
    """Aggregate mean demand at 9x is ~60 % of raw capacity (bursty trace
    saturates during overlaps — §3 calibration)."""
    n = traces.PEAK_DENSITY * 12
    total = traces.fn_rates(n, seed=0).sum()
    capacity = 12 / traces.MEAN_EXEC_S
    assert 0.45 * capacity < total < 0.75 * capacity


@given(st.sampled_from(["azure2021", "random", "resctl", "resctl-parallel",
                        "resctl-mix"]), st.integers(10, 80))
@settings(max_examples=20, deadline=None)
def test_workload_wellformed(kind, n_fns):
    wl = traces.make_workload(kind, n_fns, duration_s=10.0, seed=1)
    assert wl.n_fns == n_fns
    assert len(wl.arrivals) == n_fns
    for a in wl.arrivals:
        assert (np.diff(a) >= 0).all()
        assert ((a >= 0) & (a <= 10.0)).all()
    if kind.startswith("resctl"):
        assert wl.closed_loop_slots > 0
    if kind == "resctl-parallel":
        assert wl.parallelism == 2


def test_mix_composition():
    wl = traces.make_workload("resctl-mix", 10, seed=0)
    svc = np.concatenate(wl.service_s)
    vals, counts = np.unique(svc, return_counts=True)
    assert set(vals) == {0.010, 0.100, 1.000}


def test_lightest_band_fns():
    ids = traces.lightest_band_fns(100, 2)
    assert (traces.demand_band_of(100)[ids] < 2).all()
