"""Switch-cost model calibration against the paper's measurements."""
import numpy as np

from repro.core.switch_cost import calibration_table, switch_cost_us


def test_calibration_bands():
    t = calibration_table()
    # Fig 3c: standalone low colocation < 10 us
    assert t["standalone_low_density"] < 10.0
    # Fig 3c: standalone density 19x cross-group ~ up to 20 us
    assert 14.0 <= t["standalone_density19_cross"] <= 24.0
    # same-group switch is much cheaper (leaf-rq-only put_prev)
    assert t["standalone_density19_same"] < 0.5 * t["standalone_density19_cross"]
    # §3.2: Knative cluster node ~ 48 us
    assert 40.0 <= t["cluster_100pods_cross"] <= 58.0


def test_monotonicity():
    # cost grows with queue length, hierarchy depth, and cgroup crossing
    base = switch_cost_us(True, siblings=2, groups=10, depth=2)
    assert switch_cost_us(True, siblings=20, groups=10, depth=2) > base
    assert switch_cost_us(False, siblings=2, groups=10, depth=2) > base
    assert (
        switch_cost_us(False, siblings=2, groups=10, depth=5)
        > switch_cost_us(False, siblings=2, groups=10, depth=2)
    )


def test_vectorised():
    same = np.asarray([True, False, True])
    out = switch_cost_us(same, siblings=np.asarray([1, 4, 16]), groups=50)
    assert out.shape == (3,)
    assert out[1] > out[0]
