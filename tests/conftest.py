"""Test-suite bootstrap.

1. Puts ``src/`` on ``sys.path`` so plain ``pytest`` works without setting
   ``PYTHONPATH=src`` by hand.
2. Shims ``hypothesis`` when it isn't installed: property-based tests are
   collected and *skipped* cleanly instead of failing the whole module's
   import.  Install the real package (see requirements-dev.txt) to run them.
"""
from __future__ import annotations

import os
import sys
import types

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import pytest

    _REASON = "hypothesis not installed (pip install -r requirements-dev.txt)"

    class _Strategy:
        """Opaque stand-in: any attribute/call chain yields another stub."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):
            return _Strategy()

    def _given(*_a, **_k):
        def deco(fn):
            # zero-arg stub: hypothesis-provided params never reach pytest's
            # fixture resolution, the test just skips at run time
            def stub():
                pytest.skip(_REASON)

            stub.__name__ = getattr(fn, "__name__", "hypothesis_test")
            stub.__doc__ = getattr(fn, "__doc__", None)
            stub.__module__ = getattr(fn, "__module__", __name__)
            return stub

        return deco

    def _settings(*a, **_k):
        if a and callable(a[0]):  # bare @settings
            return a[0]

        def deco(fn):
            return fn

        return deco

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    _mod = types.ModuleType("hypothesis")
    _mod.given = _given
    _mod.settings = _settings
    _mod.assume = lambda *a, **k: True
    _mod.note = lambda *a, **k: None
    _mod.example = lambda *a, **k: (lambda fn: fn)
    _mod.HealthCheck = _Strategy()
    _mod.strategies = _Strategies("hypothesis.strategies")
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _mod.strategies
