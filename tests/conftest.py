"""Test-suite bootstrap.

1. Puts ``src/`` on ``sys.path`` so plain ``pytest`` works without setting
   ``PYTHONPATH=src`` by hand.
2. Provides a *functional* ``hypothesis`` stand-in when the real package is
   not installed (the container image has no network; see
   requirements-dev.txt).  Unlike the old shim — which collected property
   tests only to skip them — this mini-engine actually runs each
   ``@given`` test: deterministic seeded sampling per test (stable across
   runs), boundary values first, then randomized draws.  It implements the
   strategy surface this suite uses (integers, floats, lists, tuples,
   booleans, sampled_from, just, one_of) and honors
   ``settings(max_examples=...)`` scaled down by
   ``MINI_HYPOTHESIS_MAX_EXAMPLES`` (default cap 12) to keep the tier-1
   suite fast.  Install real hypothesis and it is used untouched.
"""
from __future__ import annotations

import os
import sys
import types
import zlib

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import random

    _MAX_CAP = int(os.environ.get("MINI_HYPOTHESIS_MAX_EXAMPLES", "12"))

    class _Unsatisfied(Exception):
        """Raised by ``assume(False)`` to discard the current example."""

    class _Strategy:
        """A draw function plus a list of boundary examples tried first."""

        def __init__(self, draw, corners=()):
            self._draw = draw
            self.corners = list(corners)

        def draw(self, rng):
            return self._draw(rng)

        def corner(self, i):
            return self.corners[i % len(self.corners)] if self.corners \
                else None

        def map(self, fn):
            return _Strategy(
                lambda rng: fn(self._draw(rng)),
                [fn(c) for c in self.corners],
            )

        def filter(self, pred):
            def draw(rng):
                for _ in range(100):
                    x = self._draw(rng)
                    if pred(x):
                        return x
                raise _Unsatisfied

            return _Strategy(draw, [c for c in self.corners if pred(c)])

    def _integers(min_value=None, max_value=None):
        lo = -(2 ** 31) if min_value is None else int(min_value)
        hi = 2 ** 31 if max_value is None else int(max_value)
        return _Strategy(
            lambda rng: rng.randint(lo, hi),
            [lo, hi, min(max(0, lo), hi), min(max(1, lo), hi)],
        )

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(
            lambda rng: rng.uniform(lo, hi),
            [lo, hi, (lo + hi) / 2.0],
        )

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5, [False, True])

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))], seq[:2])

    def _just(value):
        return _Strategy(lambda rng: value, [value])

    def _one_of(*strats):
        return _Strategy(
            lambda rng: strats[rng.randrange(len(strats))].draw(rng),
            [s.corner(0) for s in strats if s.corners],
        )

    def _lists(elements, min_size=0, max_size=None, unique=False,
               unique_by=None, **_kw):
        cap = min_size + 8 if max_size is None else max_size
        keyf = unique_by or (id if not unique else (lambda x: x))

        def draw(rng):
            n = rng.randint(min_size, cap)
            out, seen = [], set()
            attempts = 0
            while len(out) < n and attempts < 50 * (n + 1):
                attempts += 1
                x = elements.draw(rng)
                k = keyf(x)
                if (unique or unique_by) and k in seen:
                    continue
                seen.add(k)
                out.append(x)
            if len(out) < min_size:
                raise _Unsatisfied
            return out

        corner = []
        for i in range(min_size):
            c = elements.corner(i)
            corner.append(elements.draw(random.Random(i)) if c is None else c)
        return _Strategy(draw, [corner] if min_size <= cap else [])

    def _tuples(*strats):
        return _Strategy(
            lambda rng: tuple(s.draw(rng) for s in strats),
            [tuple(s.corner(0) for s in strats)] if all(
                s.corners for s in strats
            ) else [],
        )

    def _settings(*a, **kw):
        if a and callable(a[0]):  # bare @settings
            return a[0]

        def deco(fn):
            fn._mini_settings = dict(kw)
            return fn

        return deco

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    def _given(*strats, **kw_strats):
        def deco(fn):
            cfg = getattr(fn, "_mini_settings", {})
            n_examples = min(int(cfg.get("max_examples", _MAX_CAP)),
                             _MAX_CAP)
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode()
            )

            def runner():
                ran = 0
                trial = 0
                while ran < n_examples and trial < 10 * n_examples:
                    rng = random.Random(seed + trial)
                    trial += 1
                    try:
                        args = [
                            s.corner(trial - 1) if trial <= 2 and s.corners
                            else s.draw(rng)
                            for s in strats
                        ]
                        kwargs = {
                            k: s.draw(rng) for k, s in kw_strats.items()
                        }
                        fn(*args, **kwargs)
                    except _Unsatisfied:
                        continue
                    ran += 1

            runner.__name__ = getattr(fn, "__name__", "hypothesis_test")
            runner.__doc__ = getattr(fn, "__doc__", None)
            runner.__module__ = getattr(fn, "__module__", __name__)
            runner.hypothesis_inner = fn  # escape hatch for direct calls
            return runner

        return deco

    def _assume(cond):
        if not cond:
            raise _Unsatisfied
        return True

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.booleans = _booleans
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _st.tuples = _tuples
    _st.just = _just
    _st.one_of = _one_of

    _mod = types.ModuleType("hypothesis")
    _mod.IS_MINI = True  # tests can skip shrinker-dependent assertions
    _mod.given = _given
    _mod.settings = _settings
    _mod.assume = _assume
    _mod.note = lambda *a, **k: None
    _mod.example = lambda *a, **k: (lambda fn: fn)
    _mod.HealthCheck = types.SimpleNamespace(
        too_slow=None, data_too_large=None, filter_too_much=None,
        function_scoped_fixture=None,
    )
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
