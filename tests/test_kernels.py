"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lags_select import lags_select
from repro.kernels.ssm_scan import ssm_scan

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("B,H,S,D", [(1, 1, 128, 64), (2, 2, 256, 128),
                                     (1, 4, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
def test_flash_attention(B, H, S, D, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, D), jnp.float32).astype(dtype)
               for kk in ks)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=128, bk=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("bq,bk", [(64, 64), (128, 256)])
def test_flash_attention_blocks(bq, bk):
    B, H, S, D = 1, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q, k, v = (jax.random.normal(kk, (B, H, S, D)) for kk in ks)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,L,D", [(1, 2, 512, 64), (2, 4, 1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, L, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, L, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, L, D), jnp.float32).astype(dtype)
    kv_len = jnp.asarray([L // 2, L][:B].__mul__(1) if B == 2 else [L // 3])
    kv_len = jnp.asarray([L // 3] if B == 1 else [L // 2, L - 7])
    out = decode_attention(q, k, v, kv_len, bk=256, interpret=True)
    want = ref.decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), **TOL[dtype]
    )


@pytest.mark.parametrize("B,S,I,N", [(1, 128, 256, 8), (2, 256, 512, 16)])
@pytest.mark.parametrize("chunk,bi", [(64, 256), (128, 128)])
def test_ssm_scan(B, S, I, N, chunk, bi):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, I, 1)) - 1.0)
    dA = jnp.exp(-dt * jnp.exp(jax.random.normal(ks[1], (1, 1, I, N)) * 0.2))
    dBx = dt * jax.random.normal(ks[2], (B, S, I, N)) * 0.1
    C = jax.random.normal(ks[3], (B, S, N))
    h0 = jnp.zeros((B, I, N))
    y, h = ssm_scan(dA, dBx, C, h0, chunk=chunk, bi=min(bi, I), interpret=True)
    y_ref, h_ref = ref.ssm_scan_ref(dA, dBx, C, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


def test_ssm_scan_nonzero_h0():
    B, S, I, N = 1, 128, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    dA = jnp.clip(jax.random.uniform(ks[0], (B, S, I, N)), 0.5, 0.99)
    dBx = jax.random.normal(ks[1], (B, S, I, N)) * 0.05
    C = jax.random.normal(ks[2], (B, S, N))
    h0 = jax.random.normal(ks[3], (B, I, N))
    y, h = ssm_scan(dA, dBx, C, h0, chunk=32, bi=128, interpret=True)
    y_ref, h_ref = ref.ssm_scan_ref(dA, dBx, C, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("T,k", [(64, 4), (200, 12), (1024, 16)])
def test_lags_select(T, k):
    rng = np.random.default_rng(T)
    load = jnp.asarray(rng.uniform(0, 2, T), jnp.float32)
    credit = jnp.asarray(rng.uniform(0, 2, T), jnp.float32)
    frac = jnp.asarray(rng.uniform(0, 1, T), jnp.float32)
    runnable = jnp.asarray(rng.uniform(size=T) < 0.5)
    nl, nc, idx = lags_select(load, credit, frac, runnable, k, interpret=True)
    rl, rc, ridx, _ = ref.lags_select_ref(load, credit, frac, runnable, k)
    np.testing.assert_allclose(np.asarray(nl), np.asarray(rl), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(nc), np.asarray(rc), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


def test_lags_select_few_runnable():
    T, k = 100, 8
    runnable = jnp.zeros(T, bool).at[jnp.asarray([5, 50])].set(True)
    z = jnp.zeros(T, jnp.float32)
    credit = jnp.arange(T, dtype=jnp.float32)
    nl, nc, idx = lags_select(z, credit, z, runnable, k, interpret=True)
    assert list(np.asarray(idx)[:2]) == [5, 50]
    assert all(i == -1 for i in np.asarray(idx)[2:])
