"""End-to-end training: loss decreases; failure + resume continuity."""
import os
import subprocess
import sys

import pytest


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import main

    out = main([
        "--arch", "stablelm-1.6b", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "64", "--lr", "3e-3",
    ])
    losses = out["losses"]
    assert losses[-1] < losses[0]


def test_grad_accum_equivalence():
    """accum=2 over a doubled batch matches single-step on the same data to
    within numerical tolerance."""
    import jax
    import numpy as np

    from repro.configs.base import get_config, reduced
    from repro.train import train_loop
    from repro.train.data import DataConfig, TokenStream
    from repro.train.optimizer import OptConfig

    cfg = reduced(get_config("stablelm-1.6b"), n_layers=2)
    stream = TokenStream(cfg, 8, 32, DataConfig())
    batch = {k: jax.numpy.asarray(v) for k, v in stream.batch_at(0).items()}

    outs = {}
    for accum in (1, 2):
        tc = train_loop.TrainConfig(
            accum_steps=accum, remat=False,
            opt=OptConfig(lr=1e-3, warmup_steps=0),
        )
        step = train_loop.make_train_step(cfg, tc)
        state = train_loop.init_state(cfg, jax.random.PRNGKey(0))
        new_state, metrics = step(state, batch)
        outs[accum] = (
            float(metrics["loss"]),
            np.asarray(
                jax.tree_util.tree_leaves(new_state.params)[0], np.float32
            ),
        )
    assert abs(outs[1][0] - outs[2][0]) < 5e-3
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=2e-2, atol=2e-4)


def test_failure_resume(tmp_path):
    """Kill training mid-run, restart, verify it resumes from the checkpoint
    and finishes — the node-failure recovery path."""
    env = dict(os.environ, PYTHONPATH="src")
    args = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "stablelm-1.6b", "--reduced", "--steps", "9",
        "--batch", "2", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "3",
    ]
    p1 = subprocess.run(
        args + ["--simulate-failure", "5"],
        capture_output=True, text=True, env=env, cwd="/root/repo",
    )
    assert p1.returncode == 17  # simulated hard failure
    from repro.train import checkpoint as ckpt

    # failure hits at step 5, after the step-6 checkpoint committed
    assert ckpt.latest_step(str(tmp_path)) == 6

    p2 = subprocess.run(args, capture_output=True, text=True, env=env,
                        cwd="/root/repo")
    assert p2.returncode == 0, p2.stderr
    assert "resumed from step 6" in p2.stdout
    assert "step 8" in p2.stdout
