"""Topology-aware chaos: failure domains, network faults, the SUSPECT
tier, fencing, and proactive drain.

Covers the detection ladder (healthy -> SUSPECT -> failed) under delayed
heartbeats, the trend-detector hysteresis, the ``rack-spread`` placement,
the extended ``FaultSchedule`` grammar (validation + byte-exact JSON
round-trips, property-tested), the fencing semantics of the chaos
controller (defer, reconcile, conserve), and the serving engine's fence
windows.  Scenarios stay tiny; the full-scale sweep lives in
``benchmarks/fig_chaos_topology.py``.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.fault import HealthTracker, TrendDetector
from repro.fleet import (
    FaultEvent,
    FaultSchedule,
    Topology,
    place,
    simulate_fleet_chaos,
)
from repro.obs.report import _failover_section
from repro.scheduler.tenant import Request, Tenant
from repro.serving.engine import Engine, EngineConfig


# --- topology ----------------------------------------------------------------


def test_topology_uniform_and_queries():
    topo = Topology.uniform(10, rack_size=5)
    assert topo.n_nodes == 10 and topo.n_racks == 2
    assert topo.rack_of(0) == 0 and topo.rack_of(9) == 1
    assert list(topo.nodes_in(1)) == [5, 6, 7, 8, 9]
    assert topo.racks().tolist() == [0] * 5 + [1] * 5
    with pytest.raises(ValueError):
        topo.nodes_in(2)


def test_topology_flat_every_node_its_own_rack():
    topo = Topology.flat(4)
    assert topo.n_racks == 4
    assert [topo.rack_of(n) for n in range(4)] == [0, 1, 2, 3]


def test_topology_rejects_gappy_or_empty_racks():
    with pytest.raises(ValueError):
        Topology(rack_of_node=(0, 2))  # rack 1 missing
    with pytest.raises(ValueError):
        Topology(rack_of_node=())


def test_topology_json_round_trip_byte_exact():
    topo = Topology.uniform(6, rack_size=2, zone_racks=3)
    text = topo.to_json()
    back = Topology.from_json(text)
    assert back == topo
    assert back.to_json() == text


# --- HealthTracker: the SUSPECT tier ----------------------------------------


def test_delayed_heartbeats_with_progress_is_suspect_not_failed():
    """The crash/partition conflation fix: silence on the heartbeat
    channel plus *observed progress* must never be declared a failure."""
    tr = HealthTracker(n_hosts=2, timeout_s=5.0)
    for h in (0, 1):
        tr.heartbeat(h, now=0.0)
    tr.heartbeat(1, now=9.0)
    tr.observe_progress(0, now=9.0)  # its work keeps landing
    assert tr.failed_hosts(now=10.0) == []
    assert tr.suspect_hosts(now=10.0) == [0]


def test_suspect_becomes_failed_once_progress_goes_stale_too():
    tr = HealthTracker(n_hosts=1, timeout_s=5.0)
    tr.heartbeat(0, now=0.0)
    tr.observe_progress(0, now=4.0)
    assert tr.suspect_hosts(now=8.0) == [0]  # progress still fresh
    assert tr.failed_hosts(now=8.0) == []
    assert tr.failed_hosts(now=10.0) == [0]  # both channels stale
    assert tr.suspect_hosts(now=10.0) == []


def test_never_progressed_host_keeps_heartbeat_only_timing():
    """Hosts that never produced progress evidence fall back to the
    legacy heartbeat-only verdict — plain crash detection timing must
    not change just because the SUSPECT tier exists."""
    tr = HealthTracker(n_hosts=1, timeout_s=5.0)
    tr.heartbeat(0, now=0.0)
    assert tr.failed_hosts(now=5.5) == [0]
    assert tr.suspect_hosts(now=5.5) == []


def test_progress_timeout_s_overrides_staleness_horizon():
    tr = HealthTracker(n_hosts=1, timeout_s=2.0, progress_timeout_s=10.0)
    tr.heartbeat(0, now=0.0)
    tr.observe_progress(0, now=0.0)
    # hb long overdue at t=5, but progress is judged on the longer horizon
    assert tr.suspect_hosts(now=5.0) == [0]
    assert tr.failed_hosts(now=11.0) == [0]


# --- TrendDetector: hysteresis ----------------------------------------------


def _feed(td, host, value, others=(1, 2, 3), baseline=1.0):
    for o in others:
        td.observe(o, baseline)
    return td.observe(host, value)


def test_trend_detector_debounces_single_burst():
    td = TrendDetector(n_hosts=4, alpha=1.0, enter_ratio=1.5, persist=2)
    _feed(td, 0, 1.0)
    _feed(td, 0, 1.0)  # past warmup
    assert _feed(td, 0, 2.0) is False  # first breach: streak 1 only
    assert _feed(td, 0, 1.0) is False  # burst over, streak resets
    assert _feed(td, 0, 2.0) is False
    assert _feed(td, 0, 2.0) is True  # persisted: drains
    assert td.drain_hosts() == [0]


def test_trend_detector_hysteresis_band_never_flaps():
    td = TrendDetector(n_hosts=4, alpha=1.0, enter_ratio=1.5,
                       exit_ratio=1.2, persist=1)
    _feed(td, 0, 1.0)
    _feed(td, 0, 1.0)
    assert _feed(td, 0, 1.6) is True  # enters above 1.5
    # oscillating inside the [1.2, 1.5] dead zone: stays draining
    for v in (1.4, 1.25, 1.45, 1.3):
        assert _feed(td, 0, v) is True
    assert _feed(td, 0, 1.0) is False  # recovered below exit
    # and oscillating in the band from below never re-enters either
    for v in (1.3, 1.45, 1.35):
        assert _feed(td, 0, v) is False


def test_trend_detector_forget_drops_history():
    td = TrendDetector(n_hosts=4, alpha=1.0, enter_ratio=1.5, persist=1)
    _feed(td, 0, 1.0)
    _feed(td, 0, 1.0)
    assert _feed(td, 0, 3.0) is True
    td.forget(0)
    assert td.drain_hosts() == []
    assert 0 not in td.ewma


def test_trend_detector_rejects_inverted_band():
    with pytest.raises(ValueError):
        TrendDetector(n_hosts=2, enter_ratio=1.2, exit_ratio=1.5)


# --- schedule grammar: validation -------------------------------------------


def _topo4():
    return Topology.uniform(4, 2)


def test_rack_crash_requires_topology_and_valid_rack():
    with pytest.raises(ValueError, match="topology"):
        FaultSchedule([FaultEvent(1.0, "rack_crash", rack=0)], 4)
    with pytest.raises(ValueError, match="out of range"):
        FaultSchedule([FaultEvent(1.0, "rack_crash", rack=2)], 4, _topo4())


def test_overlapping_partitions_of_same_node_rejected():
    evs = [
        FaultEvent(1.0, "partition", nodes=(0, 1), duration=4.0),
        FaultEvent(3.0, "partition", nodes=(1, 2), duration=2.0),
    ]
    with pytest.raises(ValueError, match="overlapping partitions"):
        FaultSchedule(evs, 4)
    # disjoint windows of the same node are fine
    FaultSchedule(
        [FaultEvent(1.0, "partition", nodes=(0,), duration=1.0),
         FaultEvent(3.0, "partition", nodes=(0,), duration=1.0)], 4)


def test_heartbeat_fault_on_crashed_node_rejected():
    evs = [
        FaultEvent(1.0, "node_crash", 2),
        FaultEvent(2.0, "heartbeat_delay", 2, factor=3.0),
    ]
    with pytest.raises(ValueError):
        FaultSchedule(evs, 4)
    with pytest.raises(ValueError):
        FaultSchedule(
            [FaultEvent(1.0, "rack_crash", rack=1),
             FaultEvent(2.0, "heartbeat_loss", 3, factor=0.5)],
            4, _topo4())


def test_partition_validation_edges():
    with pytest.raises(ValueError, match="non-empty"):
        FaultSchedule([FaultEvent(1.0, "partition", duration=1.0)], 4)
    with pytest.raises(ValueError, match="duplicates"):
        FaultSchedule(
            [FaultEvent(1.0, "partition", nodes=(1, 1), duration=1.0)], 4)
    with pytest.raises(ValueError, match="out of range"):
        FaultSchedule(
            [FaultEvent(1.0, "partition", nodes=(4,), duration=1.0)], 4)
    with pytest.raises(ValueError, match="duration"):
        FaultSchedule(
            [FaultEvent(1.0, "partition", nodes=(0,), duration=0.0)], 4)


# --- schedule grammar: byte-exact JSON round-trips (property) ----------------


def test_all_event_kinds_round_trip_byte_exact():
    topo = _topo4()
    sched = FaultSchedule(
        [
            FaultEvent(0.5, "heartbeat_delay", 0, factor=2.5),
            FaultEvent(1.0, "heartbeat_loss", 1, factor=0.3),
            FaultEvent(1.5, "partition", nodes=(0, 1), duration=2.0),
            FaultEvent(2.0, "node_slow", 2, factor=3.0),
            FaultEvent(2.5, "burst_storm", factor=2.0),
            FaultEvent(3.0, "recover"),
            FaultEvent(3.5, "rack_crash", rack=1),
            FaultEvent(4.0, "node_crash", 0),
        ],
        4, topo,
    )
    text = sched.to_json()
    back = FaultSchedule.from_json(text)
    assert back.to_json() == text
    assert back.events == sched.events
    assert back.topology == topo


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=8))
def test_any_random_topology_schedule_round_trips_byte_exact(seed, n_events):
    topo = Topology.uniform(6, 2)
    sched = FaultSchedule.random(seed=seed, n_nodes=6, duration_s=30.0,
                                 n_events=n_events, topology=topo)
    text = sched.to_json()
    back = FaultSchedule.from_json(text)
    assert back.to_json() == text
    assert back.events == sched.events


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_random_without_topology_keeps_legacy_bytes(seed):
    """``random(topology=None)`` must stay byte-identical to the
    pre-topology grammar (fig_failover's seeded schedules are pinned)."""
    a = FaultSchedule.random(seed=seed, n_nodes=4, duration_s=20.0)
    b = FaultSchedule.random(seed=seed, n_nodes=4, duration_s=20.0,
                             topology=None)
    assert a.to_json() == b.to_json()
    for ev in a.events:
        assert ev.kind in ("node_crash", "node_slow", "burst_storm",
                           "recover")


# --- placement: rack-spread --------------------------------------------------


def test_rack_spread_reduces_to_spread_without_racks():
    a = place("spread", 40, 4, exec_s=0.1)
    b = place("rack-spread", 40, 4, exec_s=0.1)
    assert all(np.array_equal(x, y)
               for x, y in zip(a.node_fns, b.node_fns))


def test_rack_spread_balances_nodes_and_diversifies_racks():
    topo = Topology.uniform(4, 2)
    asg = place("rack-spread", 40, 4, exec_s=0.1, racks=topo.racks())
    assert sorted(asg.counts.tolist()) == [10, 10, 10, 10]
    with pytest.raises(ValueError, match="racks"):
        place("rack-spread", 40, 4, exec_s=0.1, racks=np.array([0, 1]))


def test_rack_spread_does_not_dogpile_a_lone_surviving_node():
    """With rack loads primary a rack reduced to one destination would
    swallow an entire failover wave; node load must lead."""
    from repro.fleet.placement import PLACEMENTS, fn_shares

    shares = fn_shares(30, exec_s=0.1)
    racks = np.array([0, 0, 1])  # rack 1 has a single live node
    init = np.array([1.0, 1.0, 1.0])
    groups = PLACEMENTS["rack-spread"](shares, 3, racks=racks,
                                       init_load=init)
    counts = [len(g) for g in groups]
    assert max(counts) - min(counts) <= 1


# --- controller integration ---------------------------------------------


def _run(schedule, topo, total=64, n_nodes=4, **kw):
    asg = place("rack-spread", total, n_nodes, exec_s=0.1,
                racks=topo.racks())
    kw.setdefault("duration_s", 12.0)
    kw.setdefault("epoch_s", 1.5)
    return simulate_fleet_chaos("lags", asg, schedule, exec_s=0.1, seed=10,
                                topology=topo, **kw)


def test_rack_crash_fails_over_every_member_and_avoids_the_rack():
    topo = _topo4()
    res = _run(FaultSchedule.single_rack_crash(1, 3.0, topo), topo)
    assert all(m.src in (2, 3) and m.dst in (0, 1) for m in res.migrations)
    rec = res.recovery_s()
    assert set(rec) == {2, 3}
    assert all(v is not None for v in rec.values())
    assert res.per_epoch_counts()[-1][2:] == [0, 0]
    assert all(sum(e.counts) == 64 for e in res.epochs)


def test_partition_fences_instead_of_double_placing():
    topo = _topo4()
    res = _run(
        FaultSchedule.single_partition((1,), 3.0, 4.5, 4, topo), topo)
    assert res.migrations == []  # never failed over: no double-placement
    fenced = {n for e in res.epochs for n in e.fenced}
    assert fenced == {1}
    assert res.lost_arrivals == 0
    assert res.replayed_arrivals >= res.deferred_arrivals > 0
    assert all(sum(e.counts) == 64 for e in res.epochs)
    # healed: the tail of the run has no suspects and no fence
    assert res.epochs[-1].suspects == [] and res.epochs[-1].fenced == []


def test_mild_heartbeat_delay_causes_no_false_positives():
    """Delay below the detection timeout must be completely invisible:
    no suspects, no fence, no migrations."""
    topo = _topo4()
    sched = FaultSchedule(
        [FaultEvent(1.5, "heartbeat_delay", 1, factor=0.5)], 4, topo)
    res = _run(sched, topo)
    assert res.migrations == []
    assert all(e.suspects == [] and e.fenced == [] for e in res.epochs)
    assert res.lost_arrivals == 0 and res.deferred_arrivals == 0


def test_proactive_drain_evacuates_trending_node_with_hysteresis():
    topo = _topo4()
    sched = FaultSchedule(
        [FaultEvent(1.5, "node_slow", 2, factor=1.8)], 4, topo)
    res = _run(sched, topo, proactive_drain=True,
               drain_enter_ratio=1.35, drain_exit_ratio=1.15)
    drained = {n for e in res.epochs for n in e.draining}
    assert drained == {2}
    moves = [m for m in res.migrations if m.src == 2]
    assert moves and all(m.dst != 2 for m in moves)
    assert all(m.cost_s >= 0.0 for m in moves)
    # reactive run under the same schedule only moves once the straggler
    # watchdog quarantines — strictly later than the proactive drain
    rea = _run(sched, topo, proactive_drain=False)
    pro_first = min(m.epoch for m in moves)
    if rea.migrations:
        assert pro_first < min(m.epoch for m in rea.migrations)
    else:
        assert pro_first >= 0


def test_proactive_drain_is_reversible_after_recover():
    topo = _topo4()
    sched = FaultSchedule(
        [FaultEvent(1.5, "node_slow", 2, factor=1.8),
         FaultEvent(7.5, "recover", 2)], 4, topo)
    res = _run(sched, topo, duration_s=18.0, proactive_drain=True,
               drain_enter_ratio=1.35, drain_exit_ratio=1.15)
    assert any(2 in e.draining for e in res.epochs)
    assert 2 not in res.epochs[-1].draining  # hysteresis exited post-heal


# --- serving engine: fence windows -------------------------------------------


def _mk_engine(policy="lags", n_tenants=8, **cfg):
    tenants = {i: Tenant(i, weight_mb=32.0) for i in range(n_tenants)}
    return Engine(EngineConfig(policy=policy, **cfg), tenants)


def test_engine_fence_window_defers_but_completes_in_flight():
    reqs = [Request(i, i % 8, 128, 8, arrival=0.002 * i) for i in range(64)]
    eng = _mk_engine()
    st = eng.run(30.0, reqs, fence_windows=[(0.05, 0.4)])
    assert st.fenced_steps > 0
    assert st.deferred > 0  # arrivals inside the window were not admitted
    assert st.sched.fenced_s > 0.0
    assert len(st.completed) == 64  # ...but everything completes post-heal
    assert st.sched.conservation_error() < 1e-6
    assert not eng.fenced  # unfenced after the run


def test_engine_fence_window_rejects_empty_window():
    eng = _mk_engine()
    with pytest.raises(ValueError):
        eng.run(1.0, [], fence_windows=[(0.5, 0.5)])


# --- report: the failover section --------------------------------------------


def test_report_renders_empty_set_for_fault_free_chaos_record():
    txt = "\n".join(_failover_section({
        "events": [], "epochs": 4, "epoch_s": 1.5,
        "completed": 40, "arrived": 40, "done_ratio": 1.0,
    }))
    assert "∅" in txt
    assert "recovery" in txt and "never" not in txt
    # no degenerate zeros presented as measurements
    assert "migrations          0" not in txt


def test_report_renders_liveness_ladder_for_partition_record():
    topo = _topo4()
    res = _run(
        FaultSchedule.single_partition((1,), 3.0, 4.5, 4, topo), topo)
    txt = "\n".join(_failover_section(res.report()))
    assert "fenced_nodes" in txt and "deferred/reconciled" in txt
    assert "per-epoch liveness" in txt
