"""repro.obs: histogram quantiles, trace export, schedstats conservation."""
import json
import math

import numpy as np
import pytest

import repro.obs as obs
from repro.obs import recorder, report, tracing
from repro.obs.metrics import Histogram, Registry
from repro.obs.schedstats import SchedStats, from_sim_result


@pytest.fixture(autouse=True)
def _obs_reset():
    """Keep the process-wide switch/registry/tracer clean per test."""
    obs.disable()
    obs.registry().reset()
    obs.uninstall_tracer()
    yield
    obs.disable()
    obs.registry().reset()
    obs.uninstall_tracer()


# -- histograms -----------------------------------------------------------
def test_histogram_quantiles_vs_numpy():
    """Log-bucketed quantiles stay within a bucket (~5 %) of numpy's."""
    rng = np.random.default_rng(0)
    for sample in (
        rng.lognormal(-2.0, 1.0, 20000),
        rng.exponential(0.3, 20000),
        rng.uniform(1e-4, 10.0, 20000),
    ):
        h = Histogram("t")
        h.record_many(sample)
        for q in (25, 50, 90, 95, 99):
            got, want = h.pct(q), float(np.percentile(sample, q))
            assert abs(got - want) / want < 0.06, (q, got, want)
        assert h.count == len(sample)
        assert abs(h.sum - sample.sum()) / sample.sum() < 1e-9
        assert h.min == sample.min() and h.max == sample.max()


def test_histogram_scalar_matches_vectorised():
    rng = np.random.default_rng(1)
    xs = rng.lognormal(0.0, 2.0, 500)
    h1, h2 = Histogram("a"), Histogram("b")
    h1.record_many(xs)
    for x in xs:
        h2.record(float(x))
    assert h1.buckets == h2.buckets
    assert h1.count == h2.count


def test_histogram_roundtrip_and_merge():
    rng = np.random.default_rng(2)
    a, b = Histogram("a"), Histogram("b")
    a.record_many(rng.exponential(1.0, 1000))
    b.record_many(rng.exponential(2.0, 1000))
    back = Histogram.from_dict(json.loads(json.dumps(a.to_dict())))
    assert back.pct(95) == a.pct(95) and back.count == a.count
    both = Histogram("m").merge(a).merge(b)
    assert both.count == 2000
    assert a.pct(50) <= both.pct(50) <= b.pct(50) + 1e-9


def test_histogram_zero_and_negative_bucket():
    h = Histogram("z")
    for x in (0.0, -1.0, 0.5, 2.0):
        h.record(x)
    assert h.zero == 2 and h.count == 4
    assert h.pct(99) <= 2.0


# -- metrics registry / disabled path ------------------------------------
def test_registry_helpers_gate_on_enabled():
    c = obs.counter("x")  # disabled -> shared null
    c.inc(5)
    assert "x" not in obs.registry().snapshot()
    obs.enable()
    obs.counter("x").inc(5)
    obs.histogram("h").record(1.0)
    snap = obs.registry().snapshot()
    assert snap["x"]["value"] == 5 and snap["h"]["count"] == 1


def test_registry_type_conflict():
    r = Registry()
    r.counter("m")
    with pytest.raises(TypeError):
        r.histogram("m")


# -- tracing --------------------------------------------------------------
def test_trace_export_roundtrip(tmp_path):
    tr = tracing.Tracer(capacity=1024)
    with tr.span("outer", cat="test", k=1):
        with tr.span("inner"):
            pass
    tr.instant("marker")
    tr.counter("runq", depth=3)
    path = tmp_path / "trace.json"
    tr.export(str(path))
    obj = json.loads(path.read_text())
    evs = obj["traceEvents"]
    assert len(evs) == 4
    for e in evs:
        assert {"name", "cat", "ph", "ts", "pid", "tid"} <= set(e)
        assert e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    # export is ts-sorted: monotonic non-decreasing timeline
    ts = [e["ts"] for e in evs]
    assert ts == sorted(ts)
    # nesting: outer event spans inner completely
    by_name = {e["name"]: e for e in evs}
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert o["ts"] + o["dur"] >= i["ts"] + i["dur"]
    assert o["args"] == {"k": 1}


def test_tracer_ring_is_bounded():
    tr = tracing.Tracer(capacity=16)
    for k in range(100):
        tr.instant(f"e{k}")
    assert len(tr) == 16
    assert tr.dropped == 84
    names = [e["name"] for e in tr.events()]
    assert names[-1] == "e99"  # newest kept, oldest dropped


def test_module_span_noop_when_disabled():
    with tracing.span("nothing") as sp:
        pass
    assert sp.tracer is None
    obs.install_tracer(capacity=8)
    with tracing.span("real"):
        pass
    assert len(obs.tracer()) == 1


def test_fenced_span_measures_jax_work():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp

    obs.install_tracer()
    with tracing.fenced_span("matmul") as sp:
        x = jnp.ones((64, 64))
        sp(x @ x)
    assert sp.dur_s > 0.0
    assert [e["name"] for e in obs.tracer().events()] == ["matmul"]


# -- schedstats ------------------------------------------------------------
def test_engine_conservation_invariant():
    """Every accounted engine second is useful, switch, or idle — the
    schedstat identity the paper's measurement model rests on."""
    from repro.scheduler.tenant import Request, Tenant
    from repro.serving.engine import Engine, EngineConfig

    tenants = {i: Tenant(i, weight_mb=64.0) for i in range(6)}
    eng = Engine(EngineConfig(policy="lags", max_resident=3), tenants)
    reqs = [Request(i, i % 6, 64, 12, arrival=0.02 * i) for i in range(30)]
    st = eng.run(20.0, reqs)
    sched = st.sched
    assert len(st.completed) == 30
    assert sched.conservation_error() < 1e-9 * max(sched.time_s, 1.0)
    # per-entity breakdown sums to totals
    assert abs(sum(e.useful_s for e in sched.entities.values())
               - sched.useful_s) < 1e-9
    assert abs(sum(e.switch_s for e in sched.entities.values())
               - sched.switch_s) < 1e-9
    # latency histogram saw every completion
    assert sched.latency.count == 30
    # compat views stay aligned with the schedstats
    assert st.useful_s == sched.useful_s
    assert st.membership_changes == int(sched.switches)


def test_simkernel_schedstats_match_result():
    from repro.core.policies import make_policy
    from repro.core.simkernel import SimConfig, Workload, simulate

    rng = np.random.default_rng(3)
    arr = [np.sort(rng.uniform(0, 10.0, 40)) for _ in range(4)]
    svc = [np.full(40, 0.05) for _ in range(4)]
    wl = Workload(4, arr, svc, threads_per_fn=4, duration_s=10.0)
    obs.enable()
    r = simulate(wl, make_policy("cfs"), SimConfig(n_cores=2))
    s = r.schedstats
    assert s is not None
    assert s.switches == float(r.switches)
    assert abs(s.switch_s - r.switch_time_s) < 1e-12
    assert abs(s.useful_s - r.busy_time_s) < 1e-12
    assert abs(sum(e.useful_s for e in s.entities.values())
               - r.busy_time_s) < 1e-9
    assert s.latency.count == r.n_completed
    assert sum(e.arrived for e in s.entities.values()) == r.n_arrived
    # capacity identity: useful + switch + idle == cores * duration
    assert abs(s.useful_s + s.switch_s + s.idle_s - s.capacity_s) < 1e-9
    # disabled -> no schedstats, result otherwise identical
    obs.disable()
    r2 = simulate(wl, make_policy("cfs"), SimConfig(n_cores=2))
    assert r2.schedstats is None
    assert r2.switches == r.switches
    summary = r2.sched_summary()
    assert summary.switches == float(r2.switches)


def test_des_schedstats():
    from repro.core.des import EventSim
    from repro.core.policies import make_policy

    sim = EventSim(n_fns=2, n_cores=1, policy=make_policy("cfs"))
    sim.submit(0, 0.0, 0.2)
    sim.submit(1, 0.0, 0.2)
    lat = sim.run(until=2.0)
    assert len(lat) == 2
    assert sim.sched.latency.count == 2
    assert abs(sim.sched.useful_s - 0.4) < 1e-9
    assert sim.switches == int(sim.sched.switches)
    assert sim.sched.run_delay.count == 2  # both requests got first-run delay


# -- recorder + report -----------------------------------------------------
def _mini_run(policy: str, switch_s: float) -> SchedStats:
    s = SchedStats(policy)
    s.account_time(10.0)
    s.account_useful(0, 10.0 - switch_s)
    s.account_switch(0, switch_s, n=5)
    for i in range(20):
        s.account_completion(0, 0.1 + 0.01 * i)
    return s


def test_record_load_and_diff(tmp_path):
    pa = recorder.record_run(
        str(tmp_path / "fair"), {"policy": "fair"}, sched=_mini_run("fair", 2.0)
    )
    recorder.record_run(
        str(tmp_path / "lags"), {"policy": "lags"}, sched=_mini_run("lags", 1.0)
    )
    run_a = recorder.load_run(str(tmp_path / "fair"))
    run_b = recorder.load_run(str(tmp_path / "lags"))
    assert run_a["sched"].switch_share == pytest.approx(0.2)
    text = report.diff(run_a, run_b)
    assert "switch_share" in text and "p99_latency" in text
    assert "lower switch-time share: lags" in text
    # CLI entry point over the same dirs
    out = report.main([str(tmp_path / "fair"), str(tmp_path / "lags")])
    assert "fair" in out and "lags" in out
    out = report.main(
        ["--diff", str(tmp_path / "fair"), str(tmp_path / "lags")]
    )
    assert "lower switch-time share: lags" in out


def test_record_run_includes_trace(tmp_path):
    obs.install_tracer()
    with tracing.span("ev"):
        pass
    recorder.record_run(str(tmp_path), {"policy": "x"})
    obj = json.loads((tmp_path / "trace.json").read_text())
    assert [e["name"] for e in obj["traceEvents"]] == ["ev"]
    run = recorder.load_run(str(tmp_path))
    assert run["trace_file"] == "trace.json"


def test_serve_cli_obs_smoke(tmp_path):
    """launch/serve.py end-to-end with telemetry + report diff."""
    from repro.launch import serve

    for pol in ("fair", "lags"):
        serve.main([
            "--policy", pol, "--tenants", "8", "--duration", "2",
            "--obs-dir", str(tmp_path / pol), "--trace",
        ])
        obs.disable()
        obs.uninstall_tracer()
    out = report.main(
        ["--diff", str(tmp_path / "fair"), str(tmp_path / "lags")]
    )
    assert "switch_share" in out and "p99_latency" in out
    trace = json.loads((tmp_path / "lags" / "trace.json").read_text())
    assert trace["traceEvents"], "trace should contain engine.step events"
    ts = [e["ts"] for e in trace["traceEvents"]]
    assert ts == sorted(ts) and all(t >= 0 for t in ts)
