"""Serving engine + paged KV allocator + admission policies."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.scheduler.admission import (
    DEFAULT_PREEMPT_HYSTERESIS,
    pick_admissions,
    should_preempt,
)
from repro.scheduler.tenant import Request, Tenant
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvcache import PagedAllocator


@given(st.lists(st.tuples(st.integers(1, 2000), st.booleans()), max_size=40))
@settings(max_examples=40, deadline=None)
def test_allocator_conservation(ops):
    """Pages are conserved across arbitrary alloc/free sequences."""
    a = PagedAllocator(n_pages=64, page_tokens=128)
    live = {}
    for i, (tokens, do_free) in enumerate(ops):
        if do_free and live:
            sid = next(iter(live))
            a.free(sid)
            live.pop(sid)
        else:
            pages = a.allocate(i, tokens)
            if pages is not None:
                live[i] = len(pages)
    assert a.free_pages + sum(len(v) for v in a.owner.values()) == 64
    assert a.free_pages == 64 - sum(live.values())


def test_allocator_rejects_when_full():
    a = PagedAllocator(n_pages=4, page_tokens=128)
    assert a.allocate(0, 512) is not None
    assert a.allocate(1, 1) is None
    a.free(0)
    assert a.allocate(1, 1) is not None


def _mk_engine(policy, n_tenants=8, **cfg):
    tenants = {i: Tenant(i, weight_mb=32.0) for i in range(n_tenants)}
    return Engine(EngineConfig(policy=policy, **cfg), tenants), tenants


def test_engine_completes_all_requests():
    eng, tenants = _mk_engine("lags")
    reqs = [Request(i, i % 8, 128, 8, arrival=0.0) for i in range(24)]
    st = eng.run(30.0, reqs)
    assert len(st.completed) == 24
    # all pages released after completion
    assert eng.alloc.free_pages == eng.alloc.n_pages


def test_lags_admission_drains_lightest():
    tenants = {0: Tenant(0), 1: Tenant(1)}
    tenants[0].credit = 1.0
    tenants[1].credit = 0.0
    tenants[0].queue.extend(Request(i, 0, 10, 5, 0.0) for i in range(3))
    tenants[1].queue.extend(Request(10 + i, 1, 10, 5, 0.0) for i in range(3))
    out = pick_admissions("lags", tenants, free_slots=4, running_tenants=set())
    # lightest tenant (1) fully drained before tenant 0 gets slots
    assert [r.tenant for r in out] == [1, 1, 1, 0]


def test_fair_admission_round_robins():
    tenants = {0: Tenant(0), 1: Tenant(1)}
    tenants[0].last_admit = 5.0
    tenants[1].last_admit = 1.0
    tenants[0].queue.extend(Request(i, 0, 10, 5, 0.0) for i in range(3))
    tenants[1].queue.extend(Request(10 + i, 1, 10, 5, 0.0) for i in range(3))
    out = pick_admissions("fair", tenants, free_slots=4, running_tenants=set())
    assert [r.tenant for r in out] == [1, 0, 1, 0]


def test_preempt_hysteresis_boundary():
    """Documented boundary (EngineConfig.preempt_hysteresis): a waiting
    tenant evicts only when its credit is *strictly below*
    hysteresis * victim_credit; equality runs to completion."""
    assert DEFAULT_PREEMPT_HYSTERESIS == 0.5
    tenants = {0: Tenant(0), 1: Tenant(1)}
    tenants[0].credit = 1.0
    tenants[1].credit = 0.5  # wait == h * run exactly
    tenants[1].queue.append(Request(0, 1, 10, 5, 0.0))
    assert should_preempt("lags", tenants, {0}) == (False, -1)
    tenants[1].credit = 0.5 - 1e-6  # just under the boundary
    assert should_preempt("lags", tenants, {0}) == (True, 0)
    # node-simulator setting: hysteresis 1.0 fires on any lighter waiter
    tenants[1].credit = 0.99
    assert should_preempt("lags", tenants, {0}, hysteresis=1.0) == (True, 0)
    assert should_preempt("lags", tenants, {0}, hysteresis=0.5) == (False, -1)


def test_engine_config_hysteresis_controls_eviction():
    """The same credit state evicts under hysteresis 1.0 but runs to
    completion under the engine default 0.5."""

    def run(h):
        eng, tenants = _mk_engine(
            "lags", n_tenants=2, n_slots=1, preempt_hysteresis=h
        )
        eng.submit(Request(0, 0, 10, 400, 0.0))
        eng.step()
        assert {r.tenant for r in eng.running} == {0}
        tenants[0].credit = 1.0
        tenants[1].credit = 0.6
        eng.submit(Request(1, 1, 10, 5, 0.0))
        eng.step()
        return {r.tenant for r in eng.running}

    assert run(1.0) == {1}  # 0.6 < 1.0: tenant 0 preempted
    assert run(0.5) == {0}  # 0.6 >= 0.5: no clear gap, keep running


def test_residency_trace_events():
    """HBM residency churn is traced: swap-in/evict instants plus an
    occupancy counter track, all on the sim clock."""
    from repro.obs import metrics as obs_metrics
    from repro.obs import tracing as obs_tracing

    tr = obs_tracing.install()
    try:
        eng, _ = _mk_engine("fair", n_tenants=8, max_resident=2, n_slots=4)
        reqs = [Request(i, i % 8, 32, 4, arrival=0.0) for i in range(16)]
        eng.run(20.0, reqs)
        events = tr.events()
        swaps = [e for e in events if e["name"] == "hbm.swap_in"]
        assert swaps and all(e["ph"] == "i" for e in swaps)
        assert {"tenant", "mb"} <= set(swaps[0]["args"])
        assert any(e["name"] == "hbm.evict" for e in events)
        counters = [e for e in events if e["name"] == "hbm.resident"]
        assert counters and all(e["ph"] == "C" for e in counters)
        assert all(e["args"]["tenants"] <= 8 for e in counters)
        # sim clock, not wall clock: timestamps stay within the run window
        assert all(0.0 <= e["ts"] <= 20.0 * 1e6 for e in swaps + counters)
    finally:
        obs_tracing.uninstall()
        obs_metrics.disable()


def test_lags_latency_beats_fair_bursty():
    from repro.core.traces import _mmpp_arrivals

    def run(policy, seed=5):
        rng = np.random.default_rng(seed)
        tenants = {i: Tenant(i, weight_mb=float(rng.uniform(32, 128)))
                   for i in range(48)}
        rates = np.logspace(-1, 0.8, 48)
        rates *= 26.0 / rates.sum()
        reqs, rid = [], 0
        for t in range(48):
            for a in _mmpp_arrivals(rates[t], 40.0, rng, 1.0, 9.0):
                reqs.append(Request(rid, t, int(rng.integers(64, 256)),
                                    int(rng.integers(16, 96)), float(a)))
                rid += 1
        eng = Engine(EngineConfig(policy=policy, max_resident=12), tenants)
        st = eng.run(40.0, reqs)
        lat = np.asarray([r.latency for r in st.completed])
        return np.median(lat), st

    p50_fair, _ = run("fair")
    p50_lags, _ = run("lags")
    assert p50_lags <= p50_fair * 1.05


def test_admission_deadline_expires_queued_work():
    """Requests never admitted within the deadline expire (counted, not
    served late); preempted work (already started) is exempt."""
    eng, tenants = _mk_engine(
        "fair", n_tenants=2, n_slots=1, admission_timeout_s=0.05)
    eng.submit(Request(0, 0, 16, 2000, 0.0))  # hogs the only slot
    eng.step()
    assert {r.rid for r in eng.running} == {0}
    eng.submit(Request(1, 1, 16, 4, 0.0))  # will never be admitted in time
    started = Request(2, 1, 16, 4, 0.0)
    started.start_time = 0.0  # looks preempted: deadline does not apply
    tenants[1].queue.append(started)
    while eng.stats.time_s < 1.0:
        eng.step()
    assert eng.stats.expired == 1
    assert started in list(tenants[1].queue)
    assert all(r.rid != 1 for r in eng.stats.completed)


def test_out_of_pages_backoff_then_completes():
    """Out-of-pages rejection parks the request with exponential backoff
    (no silent head-requeue); it completes once pages free up."""
    eng, _ = _mk_engine(
        "lags", n_tenants=2, n_slots=4, n_pages=4, page_tokens=16)
    reqs = [
        Request(0, 0, 48, 8, 0.0),  # 56 tokens -> all 4 pages
        Request(1, 1, 16, 4, 0.0),  # 2 pages -> rejected while 0 runs
    ]
    st = eng.run(5.0, reqs)
    assert st.backoffs >= 1
    assert {r.rid for r in st.completed} == {0, 1}
    done1 = next(r for r in st.completed if r.rid == 1)
    assert done1.rejections >= 1
    assert eng.alloc.free_pages == eng.alloc.n_pages


def test_shed_overload_drop_sheds_highest_credit_first():
    eng, tenants = _mk_engine("lags", n_tenants=4, n_slots=1,
                              shed_watermark=4)
    for i, t in tenants.items():
        t.credit = float(i)  # tenant 3 = most-served = shed first
    reqs = [Request(i, i % 4, 16, 4, 0.0) for i in range(12)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    assert eng.stats.shed == 8  # depth 12 -> watermark 4
    assert len(tenants[3].queue) == 0  # highest credit emptied first
    depth = sum(len(t.queue) for t in tenants.values())
    assert depth + len(eng.running) + eng.stats.shed \
        + len(eng.stats.completed) == 12


def test_shed_overload_truncate_serves_everything_shorter():
    eng, _ = _mk_engine("lags", n_tenants=2, n_slots=2,
                        shed_watermark=2, shed_mode="truncate")
    reqs = [Request(i, i % 2, 16, 32, 0.0) for i in range(8)]
    st = eng.run(30.0, reqs)
    assert st.shed > 0
    assert len(st.completed) == 8  # truncation never drops work
    trunc = [r for r in st.completed if r.truncated]
    assert trunc and all(r.max_new == 16 for r in trunc)  # halved once


def test_engine_real_model_backend():
    import jax

    from repro.configs.base import get_config, reduced
    from repro.models import model as model_lib

    cfg = reduced(get_config("qwen3-8b"), n_layers=2)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    eng, _ = _mk_engine("lags", n_slots=4)
    eng.attach_model(cfg, params, max_len=16)
    reqs = [Request(i, i % 8, 32, 4, arrival=0.0) for i in range(8)]
    st = eng.run(5.0, reqs)
    assert len(st.completed) >= 4
    assert eng._cache_len > 0  # real decode steps ran
