"""Serving engine + paged KV allocator + admission policies."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.scheduler.admission import pick_admissions
from repro.scheduler.tenant import Request, Tenant
from repro.serving.engine import Engine, EngineConfig
from repro.serving.kvcache import PagedAllocator


@given(st.lists(st.tuples(st.integers(1, 2000), st.booleans()), max_size=40))
@settings(max_examples=40, deadline=None)
def test_allocator_conservation(ops):
    """Pages are conserved across arbitrary alloc/free sequences."""
    a = PagedAllocator(n_pages=64, page_tokens=128)
    live = {}
    for i, (tokens, do_free) in enumerate(ops):
        if do_free and live:
            sid = next(iter(live))
            a.free(sid)
            live.pop(sid)
        else:
            pages = a.allocate(i, tokens)
            if pages is not None:
                live[i] = len(pages)
    assert a.free_pages + sum(len(v) for v in a.owner.values()) == 64
    assert a.free_pages == 64 - sum(live.values())


def test_allocator_rejects_when_full():
    a = PagedAllocator(n_pages=4, page_tokens=128)
    assert a.allocate(0, 512) is not None
    assert a.allocate(1, 1) is None
    a.free(0)
    assert a.allocate(1, 1) is not None


def _mk_engine(policy, n_tenants=8, **cfg):
    tenants = {i: Tenant(i, weight_mb=32.0) for i in range(n_tenants)}
    return Engine(EngineConfig(policy=policy, **cfg), tenants), tenants


def test_engine_completes_all_requests():
    eng, tenants = _mk_engine("lags")
    reqs = [Request(i, i % 8, 128, 8, arrival=0.0) for i in range(24)]
    st = eng.run(30.0, reqs)
    assert len(st.completed) == 24
    # all pages released after completion
    assert eng.alloc.free_pages == eng.alloc.n_pages


def test_lags_admission_drains_lightest():
    tenants = {0: Tenant(0), 1: Tenant(1)}
    tenants[0].credit = 1.0
    tenants[1].credit = 0.0
    tenants[0].queue.extend(Request(i, 0, 10, 5, 0.0) for i in range(3))
    tenants[1].queue.extend(Request(10 + i, 1, 10, 5, 0.0) for i in range(3))
    out = pick_admissions("lags", tenants, free_slots=4, running_tenants=set())
    # lightest tenant (1) fully drained before tenant 0 gets slots
    assert [r.tenant for r in out] == [1, 1, 1, 0]


def test_fair_admission_round_robins():
    tenants = {0: Tenant(0), 1: Tenant(1)}
    tenants[0].last_admit = 5.0
    tenants[1].last_admit = 1.0
    tenants[0].queue.extend(Request(i, 0, 10, 5, 0.0) for i in range(3))
    tenants[1].queue.extend(Request(10 + i, 1, 10, 5, 0.0) for i in range(3))
    out = pick_admissions("fair", tenants, free_slots=4, running_tenants=set())
    assert [r.tenant for r in out] == [1, 0, 1, 0]


def test_lags_latency_beats_fair_bursty():
    from repro.core.traces import _mmpp_arrivals

    def run(policy, seed=5):
        rng = np.random.default_rng(seed)
        tenants = {i: Tenant(i, weight_mb=float(rng.uniform(32, 128)))
                   for i in range(48)}
        rates = np.logspace(-1, 0.8, 48)
        rates *= 26.0 / rates.sum()
        reqs, rid = [], 0
        for t in range(48):
            for a in _mmpp_arrivals(rates[t], 40.0, rng, 1.0, 9.0):
                reqs.append(Request(rid, t, int(rng.integers(64, 256)),
                                    int(rng.integers(16, 96)), float(a)))
                rid += 1
        eng = Engine(EngineConfig(policy=policy, max_resident=12), tenants)
        st = eng.run(40.0, reqs)
        lat = np.asarray([r.latency for r in st.completed])
        return np.median(lat), st

    p50_fair, _ = run("fair")
    p50_lags, _ = run("lags")
    assert p50_lags <= p50_fair * 1.05


def test_engine_real_model_backend():
    import jax

    from repro.configs.base import get_config, reduced
    from repro.models import model as model_lib

    cfg = reduced(get_config("qwen3-8b"), n_layers=2)
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    eng, _ = _mk_engine("lags", n_slots=4)
    eng.attach_model(cfg, params, max_len=16)
    reqs = [Request(i, i % 8, 32, 4, arrival=0.0) for i in range(8)]
    st = eng.run(5.0, reqs)
    assert len(st.completed) >= 4
    assert eng._cache_len > 0  # real decode steps ran
