"""Optimizer, data pipeline, checkpointing, grad compression, fault logic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs.base import get_config, reduced
from repro.distributed import fault, grad_compress
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt
from repro.train.data import DataConfig, TokenStream


# --- optimizer --------------------------------------------------------------


def test_adamw_minimises_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_state(params)
    cfg = opt.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                        weight_decay=0.0)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, m = opt.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clip_norm():
    params = {"w": jnp.zeros(3)}
    state = opt.init_state(params)
    cfg = opt.OptConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    _, _, metrics = opt.apply_updates(
        params, {"w": jnp.asarray([1e4, 0.0, 0.0])}, state, cfg
    )
    assert float(metrics["grad_norm"]) > 1e3  # raw norm reported


def test_schedule_shape():
    cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(opt.schedule(cfg, 0)) < 0.11
    assert float(opt.schedule(cfg, 10)) == pytest.approx(1.0, rel=0.01)
    assert float(opt.schedule(cfg, 100)) < 0.2


# --- data -------------------------------------------------------------------


def test_data_deterministic_and_shardwise_distinct():
    cfg = reduced(get_config("qwen3-8b"))
    s0 = TokenStream(cfg, 4, 32, DataConfig(), shard=0, n_shards=2)
    s0b = TokenStream(cfg, 4, 32, DataConfig(), shard=0, n_shards=2)
    s1 = TokenStream(cfg, 4, 32, DataConfig(), shard=1, n_shards=2)
    a, b, c = s0.batch_at(7), s0b.batch_at(7), s1.batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].min() >= 0


# --- checkpoint -------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    d = str(tmp_path)
    ckpt.save(d, 3, tree)
    assert ckpt.latest_step(d) == 3
    assert ckpt.verify(d, 3)
    back = ckpt.restore(d, 3, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_atomicity(tmp_path):
    """A torn write (leftover .tmp) is never visible as a checkpoint."""
    d = str(tmp_path)
    tree = {"a": jnp.ones(3)}
    ckpt.save(d, 1, tree)
    os.makedirs(os.path.join(d, "step_00000002.tmp"))
    assert ckpt.latest_step(d) == 1


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path)
    tree = {"a": jnp.ones(8)}
    path = ckpt.save(d, 5, tree)
    fn = [f for f in os.listdir(path) if f.endswith(".npy")][0]
    with open(os.path.join(path, fn), "r+b") as f:
        f.seek(-1, 2)
        f.write(b"\x7f")
    assert not ckpt.verify(d, 5)


# --- grad compression -------------------------------------------------------


@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_int8_compress_error_bounded(seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256) * rng.uniform(0.01, 10))
    q, scale = grad_compress.compress(g)
    back = grad_compress.decompress(q, scale)
    err = np.abs(np.asarray(back - g)).max()
    assert err <= float(scale) * 0.5 + 1e-9  # half-ULP of the quant grid


def test_error_feedback_unbiased():
    """With error feedback, the running sum of decompressed grads tracks
    the true sum (bias -> 0)."""
    rng = np.random.default_rng(0)
    residual = jnp.zeros(64)
    tot_true = np.zeros(64)
    tot_sent = np.zeros(64)
    for _ in range(200):
        g = jnp.asarray(rng.standard_normal(64))
        q, s, residual = grad_compress.compress_with_feedback(g, residual)
        tot_true += np.asarray(g)
        tot_sent += np.asarray(grad_compress.decompress(q, s))
    drift = np.abs(tot_sent - tot_true).max()
    assert drift < 0.2  # bounded by one quantisation step


def test_topk_roundtrip():
    g = jnp.asarray(np.random.default_rng(1).standard_normal((8, 8)))
    vals, idx = grad_compress.topk_compress(g, frac=0.25)
    back = grad_compress.topk_decompress(vals, idx, g.shape)
    kept = np.asarray(back) != 0
    assert kept.sum() == 16
    np.testing.assert_allclose(np.asarray(back)[kept],
                               np.asarray(g)[kept], rtol=1e-6)


# --- fault tolerance --------------------------------------------------------


def test_health_tracker():
    h = fault.HealthTracker(4, timeout_s=10)
    for host in range(4):
        h.heartbeat(host, now=100.0)
    h.heartbeat(2, now=150.0)
    assert h.failed_hosts(now=155.0) == [0, 1, 3]
    assert h.healthy_hosts(now=105.0) == [0, 1, 2, 3]


def test_plan_remesh():
    assert fault.plan_remesh(512) == ((2, 16, 16), ("pod", "data", "model"))
    shape, axes = fault.plan_remesh(504)
    assert shape[-1] == 16 and np.prod(shape) <= 504
    with pytest.raises(ValueError):
        fault.plan_remesh(8, model_parallel=16)


def test_straggler_watchdog():
    w = fault.StragglerWatchdog(n_hosts=2, warmup=4)
    flagged = False
    for i in range(30):
        w.observe(0, 0.10)
        flagged |= w.observe(1, 0.10 if i < 20 else 0.50)
    assert flagged
