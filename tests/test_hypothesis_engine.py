"""Property-testing engine contract: real hypothesis and the mini fallback.

The suite must behave under both engines (see tests/conftest.py and
requirements-dev.txt): property tests actually execute examples, honor
``settings(max_examples=...)`` and ``assume``, and — under the *real*
engine only (CI installs it; the container falls back to the mini one) —
failures shrink to a minimal counterexample.
"""
import hypothesis
import pytest
from hypothesis import assume, given, settings
from hypothesis import strategies as st

IS_MINI = getattr(hypothesis, "IS_MINI", False)

_runs = {"n": 0, "max_seen": 0}


@settings(max_examples=8)
@given(st.integers(min_value=0, max_value=100))
def test_given_actually_runs_examples(x):
    _runs["n"] += 1
    _runs["max_seen"] = max(_runs["max_seen"], x)
    assert 0 <= x <= 100


def test_examples_were_executed():
    """Ordered after the @given test in-file: the engine ran it, more than
    once, and drew varied data (the old shim collected-and-skipped)."""
    assert _runs["n"] >= 2
    assert _runs["max_seen"] > 0  # boundary values include the upper end


@given(st.lists(st.integers(min_value=-50, max_value=50)), st.booleans())
def test_assume_filters_examples(xs, flip):
    assume(len(xs) != 1)
    assert len(xs) != 1
    total = sum(xs)
    assert sum(reversed(xs)) == total  # order-free under either engine


@pytest.mark.skipif(
    IS_MINI, reason="shrinking needs the real hypothesis engine "
                    "(pip install -r requirements-dev.txt)")
def test_real_engine_shrinks_to_minimal_counterexample():
    """`find` returns the *smallest* satisfying example — the shrinker is
    live, so a failing property test in CI reports a minimal repro."""
    assert hypothesis.find(st.integers(min_value=0), lambda x: x >= 13) == 13
    xs = hypothesis.find(
        st.lists(st.integers(min_value=0, max_value=9)),
        lambda v: sum(v) >= 15,
    )
    assert sum(xs) >= 15
    assert len(xs) <= 3  # shrunk: no redundant elements survive


def test_engine_identity_is_reported():
    """conftest marks its stand-in so tests can gate on shrinker features;
    the real package must NOT carry the marker."""
    if IS_MINI:
        assert not hasattr(hypothesis, "__version__")
    else:
        assert hasattr(hypothesis, "__version__")
