"""HLO parser: computation graph, trip counts, collective attribution."""
from repro.launch.hlo_analysis import (
    collective_stats_attributed,
    parse_computations,
)

SYNTH = """\
HloModule jit_step

%body.1 (arg: (s32[], bf16[8,128])) -> (s32[], bf16[8,128]) {
  %p = (s32[], bf16[8,128]) parameter(0)
  %ag.1 = bf16[8,128]{1,0} all-gather(%x), replica_groups={}, dimensions={0}
  %ar.1 = f32[4,64]{1,0} all-reduce(%y), to_apply=%add
  ROOT %t = (s32[], bf16[8,128]) tuple(%i, %ag.1)
}

%cond.1 (arg: (s32[], bf16[8,128])) -> pred[] {
  %p2 = (s32[], bf16[8,128]) parameter(0)
  %c = s32[] constant(24)
  ROOT %cmp = pred[] compare(%i2, %c), direction=LT
}

ENTRY %main.9 (a: bf16[8,128]) -> bf16[8,128] {
  %w = (s32[], bf16[8,128]) while(%init), condition=%cond.1, body=%body.1
  %ag.2 = bf16[16,16]{1,0} all-gather(%z), dimensions={0}
  ROOT %r = bf16[8,128] get-tuple-element(%w), index=1
}
"""


def test_parse_computations():
    comps = parse_computations(SYNTH)
    assert set(comps) == {"body.1", "cond.1", "main.9"}
    assert comps["main.9"]["entry"]
    assert comps["body.1"]["collectives"][0][0] == "all-gather"
    assert comps["cond.1"]["consts"] == [24]
    assert comps["main.9"]["whiles"] == [("cond.1", "body.1")]


def test_trip_attribution():
    stats = collective_stats_attributed(SYNTH)
    # in-loop all-gather: 8*128*2 bytes * 24 trips
    assert stats["all-gather"]["bytes"] == 8 * 128 * 2 * 24 + 16 * 16 * 2
    # in-loop all-reduce: 4*64*4 bytes * factor 2 * 24
    assert stats["all-reduce"]["bytes"] == 4 * 64 * 4 * 2 * 24
    assert stats["total_bytes"] == (
        stats["all-gather"]["bytes"] + stats["all-reduce"]["bytes"]
    )


def test_no_entry_fallback():
    txt = SYNTH.replace("ENTRY ", "")
    stats = collective_stats_attributed(txt)
    assert stats["total_bytes"] > 0
