"""JAX tick simulator: agreement with the numpy engine + vmap over nodes."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import simkernel_jax as sj
from repro.core.policies import make_policy
from repro.core.simkernel import SimConfig, simulate
from repro.core.traces import make_workload


def _setup(n_fns=40, dur=15.0, seed=3, threads=8):
    wl = make_workload("azure2021", n_fns, duration_s=dur, seed=seed,
                       threads_per_fn=threads)
    trace = sj.build_slot_trace(wl, n_fns, threads)
    return wl, trace


def test_matches_numpy_engine():
    wl, trace = _setup()
    for name, code in (("cfs", sj.CFS), ("lags", sj.LAGS)):
        p = sj.SimParams(n_cores=12, n_fns=40, n_ticks=int(15.0 / sj.TICK),
                         policy=code)
        out = sj.simulate(trace, p)
        lat = sj.latencies_from(trace, out["done_tick"])
        wl2 = make_workload("azure2021", 40, duration_s=15.0, seed=3,
                            threads_per_fn=8)
        r = simulate(wl2, make_policy(name), SimConfig())
        # same completion count, comparable medians and overhead
        assert abs(len(lat) - r.n_completed) <= max(3, 0.05 * r.n_completed)
        assert abs(np.median(lat) - r.pct(50)) < 0.25 * max(r.pct(50), 0.05)
        ovh_jax = float(out["overhead_s"]) / (12 * 15.0)
        assert abs(ovh_jax - r.overhead_frac) < 0.05


def test_vmap_over_nodes():
    """Cluster-scale: many simulated nodes in one jit via vmap."""
    _, trace = _setup(n_fns=10, dur=5.0, threads=4)
    stacked = jax.tree_util.tree_map(
        lambda x: jnp.stack([x, x]), trace
    )
    p = sj.SimParams(n_cores=4, n_fns=10, n_ticks=int(5.0 / sj.TICK))
    out = jax.vmap(lambda t: sj.simulate(t, p))(stacked)
    assert out["done_tick"].shape[0] == 2
    # identical traces -> identical results
    np.testing.assert_array_equal(
        np.asarray(out["done_tick"][0]), np.asarray(out["done_tick"][1])
    )


def test_jit_cache_and_grad_free():
    _, trace = _setup(n_fns=6, dur=2.0, threads=2)
    p = sj.SimParams(n_cores=2, n_fns=6, n_ticks=int(2.0 / sj.TICK))
    out1 = sj.simulate(trace, p)
    out2 = sj.simulate(trace, p)
    np.testing.assert_array_equal(np.asarray(out1["done_tick"]),
                                  np.asarray(out2["done_tick"]))
