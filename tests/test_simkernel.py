"""Node-simulator invariants + paper-level behaviour checks."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.policies import make_policy
from repro.core.simkernel import SimConfig, SimResult, Workload, simulate
from repro.core.traces import make_workload


def _tiny_workload(n_fns=4, rate=2.0, dur=10.0, seed=0, threads=4):
    rng = np.random.default_rng(seed)
    arr, svc = [], []
    for f in range(n_fns):
        n = rng.poisson(rate * dur)
        arr.append(np.sort(rng.uniform(0, dur, n)))
        svc.append(np.full(n, 0.05))
    return Workload(n_fns, arr, svc, threads, duration_s=dur)


@given(st.integers(1, 5), st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_conservation(n_fns, seed):
    """Completed work + switch time <= core capacity; counts consistent."""
    wl = _tiny_workload(n_fns=n_fns, seed=seed)
    r = simulate(wl, make_policy("cfs"), SimConfig(n_cores=4))
    cap = 4 * wl.duration_s
    assert r.busy_time_s + r.switch_time_s <= cap + 1e-6
    assert r.n_completed <= r.n_arrived
    # latency >= service demand, up to one tick of arrival-alignment slop
    assert (r.latencies >= 0.05 - 0.0045).all()


def test_work_conservation_underload():
    """With spare capacity, everything completes with near-service latency."""
    wl = _tiny_workload(n_fns=2, rate=1.0, dur=20.0)
    r = simulate(wl, make_policy("cfs"), SimConfig(n_cores=12))
    assert r.n_completed >= r.n_arrived - 2  # tail arrivals may be in flight
    assert r.pct(50) < 0.06


@pytest.mark.parametrize("pol", ["cfs", "lags", "eevdf", "rr", "cfs-tuned"])
def test_policies_complete_work(pol):
    wl = _tiny_workload(n_fns=6, rate=2.0, dur=15.0)
    r = simulate(wl, make_policy(pol), SimConfig(n_cores=4))
    assert r.n_completed > 0.8 * r.n_arrived


def test_lags_beats_cfs_under_overload():
    """Paper Figs 8/9: at high colocation LAGS completes more within SLO
    and keeps the median flat."""
    n_fns = 19 * 12
    cfs = simulate(
        make_workload("azure2021", n_fns, duration_s=25.0, seed=1),
        make_policy("cfs"), SimConfig(),
    )
    lags = simulate(
        make_workload("azure2021", n_fns, duration_s=25.0, seed=1),
        make_policy("lags"), SimConfig(),
    )
    assert lags.throughput_slo() > 1.3 * cfs.throughput_slo()
    assert lags.pct(50) < 0.5 * cfs.pct(50)
    assert lags.overhead_frac < cfs.overhead_frac


def test_overhead_grows_with_density():
    """Paper Fig 3b: overhead grows superlinearly with colocation."""
    ovh = []
    for d in (3, 9, 19):
        r = simulate(
            make_workload("azure2021", d * 12, duration_s=20.0, seed=1),
            make_policy("cfs"), SimConfig(),
        )
        ovh.append(r.overhead_frac)
    assert ovh[0] < ovh[1] < ovh[2]
    assert ovh[2] > 0.15  # ~20-28 % at density 19


def test_switch_cost_disabled():
    wl = _tiny_workload(n_fns=8, rate=4.0, dur=10.0)
    on = simulate(wl, make_policy("cfs"), SimConfig(n_cores=2))
    off = simulate(
        _tiny_workload(n_fns=8, rate=4.0, dur=10.0),
        make_policy("cfs"), SimConfig(n_cores=2, model_switch_cost=False),
    )
    assert off.switch_time_s == 0.0
    assert off.busy_time_s >= on.busy_time_s - 1e-9


def test_resctl_closed_loop_constant():
    """resctl throughput is density-independent (paper Fig 3a)."""
    thr = []
    for d in (3, 19):
        r = simulate(
            make_workload("resctl", d * 12, duration_s=15.0, seed=1),
            make_policy("cfs"), SimConfig(),
        )
        thr.append(r.throughput_slo())
    assert abs(thr[0] - thr[1]) / max(thr[0], 1e-9) < 0.1
