"""Chaos layer: fault schedules, the epoch-driven rebalancing controller,
and the detection-stack fixes it depends on.

Scenarios stay tiny (2-5 nodes, <= 48 functions, seconds-long epochs) so
the whole file runs in tier-1 time; the full-scale failover story lives in
``benchmarks/fig_failover.py``.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed.fault import HealthTracker, StragglerWatchdog
from repro.fleet import (
    FLEET,
    FaultEvent,
    FaultSchedule,
    make_policy,
    migration_cost_s,
    place,
    simulate_fleet,
    simulate_fleet_chaos,
)
from repro.obs.recorder import load_run
from repro.obs.report import summarize


# --- schedule grammar & validation ------------------------------------------


def test_schedule_validation_rejects_bad_events():
    bad = [
        ([FaultEvent(1.0, "meteor", 0)], "unknown fault kind"),
        ([FaultEvent(-1.0, "node_crash", 0)], "time must be >= 0"),
        ([FaultEvent(1.0, "node_slow", 0, 0.5)], "factor must be >= 1"),
        ([FaultEvent(1.0, "burst_storm", 2, 2.0)], "fleet-wide"),
        ([FaultEvent(1.0, "recover", FLEET)], "no active storm"),
        ([FaultEvent(1.0, "node_crash", 7)], "out of range"),
        ([FaultEvent(1.0, "node_crash", 0),
          FaultEvent(2.0, "node_crash", 0)], "crashed twice"),
        ([FaultEvent(1.0, "node_crash", 0),
          FaultEvent(2.0, "node_slow", 0, 2.0)], "already-crashed"),
        ([FaultEvent(1.0, "recover", 0)], "neither crashed nor slow"),
        ([FaultEvent(1.0, "node_crash", 0),
          FaultEvent(1.5, "node_crash", 1)], "crashes every node"),
    ]
    for events, match in bad:
        with pytest.raises(ValueError, match=match):
            FaultSchedule(events, n_nodes=2)


def test_schedule_good_sequences_validate():
    # crash -> recover -> crash again is legal; slow then recover is legal
    FaultSchedule(
        [
            FaultEvent(1.0, "node_crash", 0),
            FaultEvent(2.0, "recover", 0),
            FaultEvent(3.0, "node_crash", 0),
            FaultEvent(1.0, "node_slow", 1, 3.0),
            FaultEvent(4.0, "recover", 1),
            FaultEvent(0.5, "burst_storm", FLEET, 2.0),
            FaultEvent(5.0, "recover", FLEET),
        ],
        n_nodes=3,
    )


def test_schedule_events_in_and_ordering():
    s = FaultSchedule(
        [FaultEvent(3.0, "node_crash", 1), FaultEvent(0.5, "node_slow", 0, 2.0)],
        n_nodes=2,
    )
    # events are normalised to time order regardless of construction order
    assert [e.t for e in s.events] == [0.5, 3.0]
    assert [e.kind for e in s.events_in(0.0, 1.0)] == ["node_slow"]
    assert [e.kind for e in s.events_in(3.0, 4.0)] == ["node_crash"]
    assert s.events_in(1.0, 3.0) == []  # t0 <= t < t1


def test_schedule_json_roundtrip_byte_stable():
    a = FaultSchedule.random(seed=11, n_nodes=4, duration_s=30.0, n_events=6)
    b = FaultSchedule.from_json(a.to_json())
    assert a.to_json() == b.to_json()
    assert a.events == b.events
    # seed-determinism: same seed, same schedule, byte-for-byte
    c = FaultSchedule.random(seed=11, n_nodes=4, duration_s=30.0, n_events=6)
    assert c.to_json() == a.to_json()
    assert FaultSchedule.random(
        seed=12, n_nodes=4, duration_s=30.0, n_events=6,
    ).to_json() != a.to_json()


# --- detection stack (satellite fixes) --------------------------------------


def test_health_tracker_grace_period_boundaries():
    h = HealthTracker(2, timeout_s=10.0)
    h.register(0, now=0.0)
    h.register(1, now=0.0)
    # a never-heartbeated host is NOT failed from t=0 (the old bug)
    assert h.failed_hosts(now=0.0) == []
    assert h.failed_hosts(now=10.0) == []  # boundary: grace is exclusive
    assert h.failed_hosts(now=10.1) == [0, 1]  # grace expired
    h.heartbeat(0, now=10.1)
    assert h.failed_hosts(now=20.0) == [1]  # 0 within timeout of heartbeat
    assert h.failed_hosts(now=20.2) == [0, 1]  # 0 timed out again
    # un-registered hosts still date from t=0
    h2 = HealthTracker(1, timeout_s=10.0)
    assert h2.failed_hosts(now=5.0) == []
    assert h2.failed_hosts(now=11.0) == [0]
    # custom grace shorter than timeout
    h3 = HealthTracker(1, timeout_s=100.0, grace_s=5.0)
    h3.register(0, now=0.0)
    assert h3.failed_hosts(now=4.0) == []
    assert h3.failed_hosts(now=6.0) == [0]


def test_straggler_watchdog_3x_stays_flagged():
    """Regression: flagged samples no longer poison the EWMA baseline, so
    a persistent 3x straggler stays flagged instead of normalising."""
    w = StragglerWatchdog(n_hosts=4, warmup=4)
    flags = []
    for i in range(60):
        for h in (0, 1, 2):
            w.observe(h, 0.10)
        flags.append(w.observe(3, 0.30 if i >= 10 else 0.10))
    # debounce: the first slow sample is only a suspect (persist=2), every
    # one after that must keep the straggler flagged
    assert not any(flags[:11])
    assert all(flags[11:]), "3x straggler must stay flagged every step"
    # its excluded samples must not have dragged the fleet mean up
    assert w.mean[3] < 0.15


def test_straggler_watchdog_tolerates_heterogeneous_fleet():
    """min_ratio guard: honest per-host mean differences (tens of percent)
    with tiny per-host variance must NOT flag anyone."""
    w = StragglerWatchdog(n_hosts=4, warmup=4)
    base = [0.08, 0.10, 0.12, 0.14]
    for _ in range(40):
        for h, b in enumerate(base):
            assert not w.observe(h, b)


# --- controller: differential, crash, straggler drain, storm ----------------


def _tiny(n_fns, n_nodes, strategy="spread", exec_s=0.1):
    return place(strategy, n_fns, n_nodes, exec_s=exec_s)


def test_empty_schedule_bit_identical_to_simulate_fleet():
    asg = _tiny(48, 2, "round-robin")
    base = simulate_fleet("lags", asg, duration_s=6.0, exec_s=0.1)
    ch = simulate_fleet_chaos(
        "lags", asg, FaultSchedule.empty(2), duration_s=6.0, exec_s=0.1)
    assert np.array_equal(base.latencies, ch.latencies)
    assert base.n_arrived == ch.n_arrived
    assert base.n_completed == ch.n_completed
    assert ch.migrations == [] and ch.lost_arrivals == 0


def test_crash_rebalance_vs_static():
    n_nodes, total = 3, 24
    asg = _tiny(total, n_nodes)
    n_victim_fns = len(asg.node_fns[1])
    crash = FaultSchedule.single_crash(1, 3.0, n_nodes)
    kw = dict(duration_s=9.0, epoch_s=1.5, exec_s=0.1, seed=10)
    reb = simulate_fleet_chaos("lags", asg, crash, rebalance=True, **kw)
    stat = simulate_fleet_chaos("lags", asg, crash, rebalance=False, **kw)

    # the dead node is drained exactly once, onto survivors only
    assert len(reb.migrations) == n_victim_fns
    assert all(m.src == 1 and m.dst != 1 for m in reb.migrations)
    assert reb.migration_s >= 0.0  # lags run-to-completion can price ~0
    last = reb.per_epoch_counts()[-1]
    assert last[1] == 0 and sum(last) == total
    assert reb.recovery_s()[1] is not None

    # static strands them for the rest of the run
    assert stat.migrations == []
    assert stat.per_epoch_counts()[-1][1] == n_victim_fns
    assert stat.recovery_s()[1] is None
    # failover drains the retry backlog; a static placement never does
    assert reb.stranded_arrivals > 0
    assert reb.replayed_arrivals == reb.stranded_arrivals
    assert reb.lost_arrivals == 0
    assert stat.replayed_arrivals == 0
    assert stat.lost_arrivals == stat.stranded_arrivals > 0
    assert reb.n_completed > stat.n_completed
    # outage demand shows up as arrived-but-lost, not silently dropped
    assert stat.n_arrived >= stat.n_completed + stat.lost_arrivals


def test_slow_node_flagged_and_drained():
    n_nodes, total = 8, 64
    asg = _tiny(total, n_nodes)
    sch = FaultSchedule([FaultEvent(0.0, "node_slow", 2, 3.0)], n_nodes)
    res = simulate_fleet_chaos(
        "lags", asg, sch, duration_s=8.0, epoch_s=1.0, exec_s=0.1, seed=7)
    assert any(2 in e.stragglers for e in res.epochs)
    assert 2 in res.report()["stragglers_drained"]
    assert res.per_epoch_counts()[-1][2] == 0  # quarantined and drained
    assert all(m.src == 2 for m in res.migrations)
    assert sum(res.per_epoch_counts()[-1]) == total


def test_burst_storm_scales_demand_then_recovers():
    n_nodes, total = 2, 16
    asg = _tiny(total, n_nodes)
    sch = FaultSchedule(
        [FaultEvent(0.0, "burst_storm", FLEET, 3.0),
         FaultEvent(2.0, "recover", FLEET)],
        n_nodes,
    )
    # memoryless epochs isolate the storm's *nominal* demand scaling from
    # the carryover of whatever the storm left unfinished
    kw = dict(duration_s=4.0, epoch_s=1.0, exec_s=0.1, seed=6,
              carry_unfinished=False)
    res = simulate_fleet_chaos("lags", asg, sch, **kw)
    calm = simulate_fleet_chaos("lags", asg, FaultSchedule.empty(n_nodes), **kw)
    storm_arr = sum(e.fleet.n_arrived for e in res.epochs[:2])
    calm_arr = sum(e.fleet.n_arrived for e in calm.epochs[:2])
    assert storm_arr > 1.5 * calm_arr
    # post-recovery epochs replay the calm run exactly (same seeds/rates)
    assert res.epochs[3].fleet.n_arrived == calm.epochs[3].fleet.n_arrived


def test_migration_cost_policy_asymmetry():
    c_cfs = migration_cost_s(make_policy("cfs"), 88)
    c_lags = migration_cost_s(make_policy("lags"), 88)
    assert c_cfs > 10 * c_lags >= 0.0
    assert migration_cost_s(make_policy("cfs"), 0) == 0.0


def test_chaos_record_and_report(tmp_path):
    n_nodes = 2
    asg = _tiny(16, n_nodes)
    res = simulate_fleet_chaos(
        "lags", asg, FaultSchedule.single_crash(0, 1.0, n_nodes),
        duration_s=4.0, epoch_s=1.0, exec_s=0.1,
        record_dir=str(tmp_path),
    )
    txt = summarize(load_run(str(tmp_path)))
    assert "failover:" in txt
    assert "node_crash" in txt
    assert f"migrations   | {len(res.migrations)}" in txt.replace("  ", " ") \
        or str(len(res.migrations)) in txt


# --- property: conservation + monotone completions --------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_any_schedule_conserves_functions(seed):
    """Any random fault schedule + rebalancing keeps every function on
    exactly one node at every epoch boundary, and cumulative completions
    never decrease across a migration."""
    n_nodes, total = 2, 8
    asg = _tiny(total, n_nodes)
    sch = FaultSchedule.random(
        seed=seed, n_nodes=n_nodes, duration_s=4.0, n_events=3)
    res = simulate_fleet_chaos(
        "lags", asg, sch, duration_s=4.0, epoch_s=1.0, exec_s=0.1, seed=3)
    for counts in res.per_epoch_counts():
        assert sum(counts) == total  # every fn on exactly one node
        assert all(c >= 0 for c in counts)
    cum = res.cumulative_completions()
    assert all(b >= a for a, b in zip(cum, cum[1:]))
    assert res.n_arrived >= res.n_completed
