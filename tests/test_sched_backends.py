"""Cross-backend differential gate for the unified policy core.

The contract (sched/protocol.py): the numpy, JAX and Pallas backends must
agree on scheduling decisions — identical picked / preempted sets — on
randomized small cases.  State is generated on a coarse 1/16 grid with a
power-of-two group count so every primary key (and the EEVDF runnable
mean) is exact in both float32 and float64: any disagreement is a formula
divergence, not rounding.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.scheduler.tenant import Request, Tenant
from repro.sched import jax_backend as jb
from repro.sched import numpy_backend as nb
from repro.sched import pallas_backend as pb
from repro.sched import protocol
from repro.sched.serving import admission_policy

POLICIES = ("cfs", "eevdf", "rr", "lags", "lags-static")
N_SEEDS = 5  # x 5 policies = 25 randomized cases (acceptance floor: 20)


def _random_case(rng, policy):
    G = 4  # power of two: the EEVDF runnable mean stays grid-exact
    T = int(rng.integers(6, 13))
    ent_group = rng.integers(0, G, T)
    grid = lambda n: rng.choice(np.arange(128), size=n, replace=False) / 16.0
    group_vrt = grid(G)
    group_credit = grid(G)
    last_pick = rng.permutation(T).astype(np.float64)
    runnable = rng.random(T) < 0.8
    if not runnable.any():
        runnable[int(rng.integers(0, T))] = True
    group_runnable = np.zeros(G, bool)
    group_runnable[np.unique(ent_group[runnable])] = True
    is_rt = np.zeros(G, bool)
    if policy == "lags-static":
        is_rt[int(rng.integers(0, G))] = True
    k = int(rng.integers(1, 5))
    return dict(ent_group=ent_group, group_vrt=group_vrt,
                group_credit=group_credit, last_pick=last_pick,
                runnable=runnable, group_runnable=group_runnable,
                is_rt=is_rt, k=k)


@pytest.mark.parametrize("policy", POLICIES)
def test_numpy_jax_primary_keys_pick_identical_sets(policy):
    """numpy and JAX primary keys admit the same entity sets."""
    spec = protocol.spec(policy)
    for seed in range(N_SEEDS):
        rng = np.random.default_rng(1000 * seed + hash(policy) % 1000)
        c = _random_case(rng, policy)

        nview = nb.EntityView(
            ent_group=c["ent_group"], group_vrt=c["group_vrt"],
            group_credit=c["group_credit"], last_pick_tick=c["last_pick"],
            runnable=c["runnable"], group_runnable=c["group_runnable"],
            is_rt_group=c["is_rt"], tick_sec=0.004,
            slice_ticks=spec.slice_ticks,
        )
        key_np = nb.primary_key(spec, nview)

        jview = jb.PolicyView(
            ent_group=jnp.asarray(c["ent_group"], jnp.int32),
            group_vrt=jnp.asarray(c["group_vrt"], jnp.float32),
            group_credit=jnp.asarray(c["group_credit"], jnp.float32),
            last_pick_tick=jnp.asarray(c["last_pick"], jnp.float32),
            runnable=jnp.asarray(c["runnable"]),
            group_runnable=jnp.asarray(c["group_runnable"]),
            is_rt_group=jnp.asarray(c["is_rt"]),
            tick_sec=0.004, slice_ticks=spec.slice_ticks,
        )
        key_jx = np.asarray(
            jb.primary_key(jb.CODE_OF[policy], jview), np.float64
        )

        np.testing.assert_allclose(key_jx, key_np, rtol=1e-6, atol=1e-6)
        picks_np = nb.pick_k(key_np, c["runnable"], c["k"])
        picks_jx = nb.pick_k(key_jx, c["runnable"], c["k"])
        assert picks_np.tolist() == picks_jx.tolist(), (
            f"{policy} seed {seed}: numpy picked {picks_np}, "
            f"jax picked {picks_jx}"
        )


def test_preemption_rule_agrees_across_backends():
    """protocol.credit_preempt, the JAX sticky-slice break and the serving
    LAGS admission policy fire on exactly the same credit states."""
    rng = np.random.default_rng(42)
    fired = set()
    for _ in range(25):
        G = int(rng.integers(2, 7))
        credit = rng.choice(np.arange(64), size=G, replace=False) / 16.0
        run_g = int(rng.integers(0, G))
        waiting = [g for g in range(G) if g != run_g]
        expect = protocol.credit_preempt(
            float(credit[waiting].min()), float(credit[run_g]), 1.0
        )
        fired.add(expect)

        # JAX backend: the running slot's slice is broken iff a strictly
        # lighter group waits — same rule, phrased as stickiness
        continuing = np.zeros(G, bool)
        continuing[run_g] = True
        view = jb.PolicyView(
            ent_group=jnp.arange(G, dtype=jnp.int32),
            group_vrt=jnp.zeros(G, jnp.float32),
            group_credit=jnp.asarray(credit, jnp.float32),
            last_pick_tick=jnp.zeros(G, jnp.float32),
            runnable=jnp.ones(G, bool),
            group_runnable=jnp.ones(G, bool),
            is_rt_group=jnp.zeros(G, bool),
            tick_sec=0.004, slice_ticks=25,
        )
        sticky = np.asarray(
            jb.sticky_mask(jb.LAGS, view, jnp.asarray(continuing))
        )
        assert bool(~sticky[run_g]) == expect

        # serving backend on the identical credit state
        tenants = {g: Tenant(g) for g in range(G)}
        for g in range(G):
            tenants[g].credit = float(credit[g])
        for g in waiting:
            tenants[g].queue.append(Request(g, g, 8, 4, 0.0))
        fire, victim = admission_policy("lags").preempt(
            tenants, {run_g}, 1.0
        )
        assert fire == expect
        if fire:
            assert victim == run_g
    assert fired == {True, False}  # both outcomes exercised


def test_preemption_boundary_equal_credits_never_fires():
    for h in (1.0, 0.5):
        tenants = {0: Tenant(0), 1: Tenant(1)}
        tenants[0].credit = 2.0
        tenants[1].credit = 2.0 * h  # wait == h * run exactly
        tenants[1].queue.append(Request(0, 1, 8, 4, 0.0))
        assert admission_policy("lags").preempt(tenants, {0}, h) == (False, -1)


# -- Pallas backend ---------------------------------------------------------

pallas_ok = pb.available()


@pytest.mark.skipif(not pallas_ok, reason="pallas unavailable")
def test_pallas_tick_matches_numpy_reference():
    """The fused kernel agrees with the float64 oracle: identical pick
    order, allclose credit state, on 20 randomized cases."""
    rng = np.random.default_rng(7)
    for case in range(20):
        T = int(rng.integers(4, 33))
        # credits distinct on a 1/16 grid; one EMA step (window 256) moves
        # them < half the spacing, so f32 vs f64 cannot reorder the picks
        credit = rng.choice(np.arange(64), size=T, replace=False) / 16.0
        load = rng.integers(0, 17, T) / 16.0
        frac = rng.integers(0, 17, T) / 16.0
        runnable = rng.random(T) < 0.7
        k = int(rng.integers(1, 9))

        nl, nc, idx = pb.tick_and_pick(
            load, credit, frac, runnable, k, window=256
        )
        rl, rc, ridx = pb.numpy_reference(
            load, credit, frac, runnable, k, window=256
        )
        np.testing.assert_allclose(nl, rl, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(nc, rc, rtol=1e-5, atol=1e-6)
        assert idx.tolist() == ridx.tolist(), f"case {case}"


@pytest.mark.skipif(not pallas_ok, reason="pallas unavailable")
def test_engine_pallas_tick_matches_python_tick():
    """Engine state after one _pallas_tick == one python Tenant.tick loop."""
    from repro.serving.engine import Engine, EngineConfig

    rng = np.random.default_rng(3)
    n = 12
    loads = rng.random(n)
    creds = rng.random(n)
    served = {i: float(rng.random() * 0.01) for i in range(0, n, 2)}
    step_s = 0.012

    ta = {i: Tenant(i) for i in range(n)}
    tb = {i: Tenant(i) for i in range(n)}
    for i in range(n):
        ta[i].load_avg = tb[i].load_avg = float(loads[i])
        ta[i].credit = tb[i].credit = float(creds[i])
    ta[1].queue.append(Request(0, 1, 8, 4, 0.0))

    eng = Engine(
        EngineConfig(policy="lags", pallas_threshold=1, credit_window=256),
        ta,
    )
    eng._pallas_tick(served, step_s)
    for i in range(n):
        tb[i].tick(served.get(i, 0.0), step_s, 256)

    np.testing.assert_allclose(
        [ta[i].load_avg for i in range(n)],
        [tb[i].load_avg for i in range(n)], rtol=1e-5, atol=1e-6,
    )
    np.testing.assert_allclose(
        [ta[i].credit for i in range(n)],
        [tb[i].credit for i in range(n)], rtol=1e-5, atol=1e-6,
    )
    assert [ta[i].served_s for i in range(n)] == \
        [tb[i].served_s for i in range(n)]


@pytest.mark.skipif(not pallas_ok, reason="pallas unavailable")
def test_engine_pallas_path_completes_like_python_path():
    from repro.serving.engine import Engine, EngineConfig

    def run(threshold):
        tenants = {i: Tenant(i, weight_mb=32.0) for i in range(6)}
        eng = Engine(
            EngineConfig(policy="lags", pallas_threshold=threshold), tenants
        )
        reqs = [Request(i, i % 6, 64, 6, arrival=0.0) for i in range(12)]
        return eng.run(8.0, reqs)

    st_py = run(0)  # kernel path disabled
    st_pl = run(1)  # kernel path forced
    assert len(st_py.completed) == len(st_pl.completed) == 12
