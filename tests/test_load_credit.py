"""Load Credit metric: PELT + EMA math, numpy/JAX agreement, properties."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import load_credit as lc


def test_pelt_halflife():
    # after exactly `halflife` ticks of zero input, load halves
    load = 1.0
    for _ in range(lc.PELT_HALFLIFE_TICKS):
        load = lc.pelt_update(load, 0.0)
    # geometric decay plus (1-y)*0 contributions
    assert abs(load - 0.5) < 0.02


def test_ema_window_response():
    # steady input converges to that input; window controls speed
    fast = slow = 0.0
    for _ in range(500):
        fast = lc.ema_update(fast, 1.0, window_ticks=100)
        slow = lc.ema_update(slow, 1.0, window_ticks=2000)
    assert fast > 0.99 and 0.2 < slow < 0.6


@given(
    st.lists(st.floats(0.0, 12.0), min_size=1, max_size=200),
    st.integers(10, 2000),
)
@settings(max_examples=50, deadline=None)
def test_credit_bounded_by_max_input(inputs, window):
    """Credit never exceeds the max running fraction seen (convexity)."""
    t = lc.LoadCreditTracker(1, window_ticks=window)
    for x in inputs:
        t.tick(np.asarray([x]))
    assert 0.0 <= t.credit[0] <= max(inputs) + 1e-9


@given(st.integers(1, 64), st.integers(0, 200))
@settings(max_examples=30, deadline=None)
def test_numpy_jax_agree(n_groups, steps):
    rng = np.random.default_rng(steps)
    tracker = lc.LoadCreditTracker(n_groups)
    state = (jnp.zeros(n_groups), jnp.zeros(n_groups))
    for _ in range(steps % 37):
        frac = rng.uniform(0, 2, n_groups)
        c_np = tracker.tick(frac)
        state, c_jax = lc.jax_tick(state, jnp.asarray(frac))
        np.testing.assert_allclose(c_np, np.asarray(c_jax), rtol=1e-5,
                                   atol=1e-7)


def test_lightest_group_ordering():
    """A group that ran less recently has lower credit (LAS property)."""
    t = lc.LoadCreditTracker(2, window_ticks=100)
    for i in range(300):
        t.tick(np.asarray([1.0, 0.2]))
    assert t.credit[1] < t.credit[0]
