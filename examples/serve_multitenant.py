"""Serve a small model with batched multi-tenant requests, comparing the
paper's LAGS admission against fair round-robin (DESIGN.md §2).

  PYTHONPATH=src python examples/serve_multitenant.py
"""
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.launch.serve import build_workload
from repro.serving.engine import Engine, EngineConfig

DURATION = 40.0

for policy in ("fair", "lags"):
    tenants, arrivals = build_workload(48, DURATION, seed=3)
    eng = Engine(EngineConfig(policy=policy, max_resident=12), tenants)
    st = eng.run(DURATION, arrivals)
    lat = np.asarray([r.latency for r in st.completed])
    print(
        f"{policy:5s}: completed={len(st.completed):4d} "
        f"p50={np.median(lat):5.2f}s slo@2s={np.mean(lat < 2)*100:3.0f}% "
        f"switch_overhead={st.overhead_frac*100:4.1f}%"
    )
print("LAGS should show lower p50 / higher SLO attainment at similar or "
      "lower switch overhead.")
