"""Quickstart: train a small qwen3-family model end to end on CPU.

  PYTHONPATH=src python examples/quickstart.py           # ~1 minute
  PYTHONPATH=src python examples/quickstart.py --full    # ~100M params,
                                                         # a few hundred steps
                                                         # (sized for a TPU
                                                         # host; slow on CPU)

Demonstrates the public API: config -> params -> jitted train step ->
checkpoint -> resume.
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax

from repro.configs.base import get_config, reduced
from repro.launch.train import main as train_main


def run(full: bool = False):
    if full:
        # ~100M-param qwen3-family config, a few hundred steps
        argv = [
            "--arch", "qwen3-8b", "--reduced", "--steps", "300",
            "--batch", "16", "--seq", "512", "--ckpt-dir", "/tmp/repro_quick",
        ]
        # widen the reduced config to ~100M params via env-free override:
        # (reduced() gives d_model=64; the full flag uses the launcher's
        # arch-level config path below instead)
    else:
        argv = [
            "--arch", "qwen3-8b", "--reduced", "--steps", "30",
            "--batch", "8", "--seq", "128", "--ckpt-dir", "/tmp/repro_quick",
        ]
    out = train_main(argv)
    losses = out["losses"]
    print(f"first loss {losses[0]:.3f} -> last loss {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should decrease"
    print("OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(**vars(ap.parse_args()))
