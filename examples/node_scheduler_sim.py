"""Reproduce the paper's core claim on one simulated worker node:
CFS collapses under dense colocation; CFS-LAGS keeps the median flat and
completes more requests within the 1 s SLO (Figs 3/8/9).

  PYTHONPATH=src python examples/node_scheduler_sim.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.policies import make_policy
from repro.core.simkernel import SimConfig, simulate
from repro.core.traces import make_workload

for density in (9, 19):
    n_fns = density * 12
    print(f"--- density {density}x ({n_fns} functions on 12 HT) ---")
    for pol in ("cfs", "lags"):
        wl = make_workload("azure2021", n_fns, duration_s=30.0, seed=1)
        r = simulate(wl, make_policy(pol), SimConfig())
        print(
            f"  {pol:4s}: thr@1s={r.throughput_slo():6.1f} rps  "
            f"p50={r.pct(50):6.3f}s  p95={r.pct(95):7.3f}s  "
            f"sched_overhead={r.overhead_frac*100:4.1f}%  "
            f"switch={r.mean_switch_cost_us:4.1f}us"
        )
