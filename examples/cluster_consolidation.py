"""Paper §5.1: consolidate a serverless cluster with CFS-LAGS fleet nodes.

  PYTHONPATH=src python examples/cluster_consolidation.py
  PYTHONPATH=src python examples/cluster_consolidation.py \
      --placements round-robin pack spread switch-aware --nodes 10

Runs the consolidation sweep through ``repro.fleet`` (placement-aware
multi-node simulation), then compares placement strategies at the
consolidated node count and renders one *merged fleet view* from the
per-node run records via ``repro.obs.report --merge``.
"""
import argparse
import glob
import sys
import tempfile

sys.path.insert(0, "src")

from repro.fleet import (  # noqa: E402
    consolidation_sweep,
    min_nodes_meeting_slo,
    placement_comparison,
)
from repro.obs import report as obs_report  # noqa: E402

ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
ap.add_argument("--total-fns", type=int, default=800)
ap.add_argument("--node-counts", type=int, nargs="+",
                default=(14, 12, 10, 9))
ap.add_argument("--nodes", type=int, default=0,
                help="node count for the placement sweep "
                     "(default: the LAGS minimum found)")
ap.add_argument("--placements", nargs="+",
                default=("round-robin", "pack", "spread", "switch-aware"))
ap.add_argument("--duration", type=float, default=0.0,
                help="sweep horizon in sim-seconds (default: the "
                     "calibrated fleet horizon)")
ap.add_argument("--backend", default="numpy", choices=("numpy", "jax"))
args = ap.parse_args()

from repro.fleet import CLUSTER_DURATION_S  # noqa: E402

dur = args.duration or CLUSTER_DURATION_S

# 1. consolidation: smallest node count per policy that holds the SLO
res = consolidation_sweep(
    total_fns=args.total_fns, node_counts=tuple(args.node_counts),
    duration_s=dur, backend=args.backend,
)
for r in res:
    print(
        f"{r.policy:4s} nodes={r.n_nodes:2d}  p95={r.p95:7.3f}s  "
        f"done={r.done_ratio*100:5.1f}%  "
        f"util={r.util_effective*100:4.0f}%eff/{r.util_perceived*100:4.0f}%perc"
        f"  overhead={r.overhead_frac*100:4.1f}%"
    )
n_cfs = min_nodes_meeting_slo(res, "cfs")
n_lags = min_nodes_meeting_slo(res, "lags")
print(f"min nodes: CFS={n_cfs}  LAGS={n_lags} "
      f"({100*(1-n_lags/max(n_cfs,1)):.0f}% reduction)")

# 2. placement sweep at the consolidated count: same functions, different
#    packing — watch the per-node p95 spread and overhead imbalance
n_sweep = args.nodes or n_lags
print(f"\nplacement sweep (lags, {n_sweep} nodes):")
rec_dir = tempfile.mkdtemp(prefix="fleet_records_")
pres = placement_comparison(
    total_fns=args.total_fns, n_nodes=n_sweep, policy="lags",
    placements=tuple(args.placements),
    duration_s=args.duration or 30.0,  # imbalance shows fine at 30 s
    record_dir=rec_dir,
)
for r in pres:
    print(
        f"{r.placement:12s}  p95={r.p95:7.3f}s  "
        f"p95_spread={r.p95_spread:6.3f}s  "
        f"ovh={r.overhead_frac*100:4.1f}%  ovh_imb={r.ovh_max_over_mean:.2f}"
    )

# 3. merged fleet view: every node emitted a run record; fold them into one
best = min(pres, key=lambda r: r.p95)
node_records = sorted(glob.glob(f"{rec_dir}/{best.placement}/node*"))
print(f"\nmerged fleet view ({best.placement}, {len(node_records)} node "
      f"records from {rec_dir}):")
obs_report.main(["--merge", *node_records])
