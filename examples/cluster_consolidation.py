"""Paper §5.1: consolidate a serverless cluster with CFS-LAGS nodes.

  PYTHONPATH=src python examples/cluster_consolidation.py
"""
import sys

sys.path.insert(0, "src")

from repro.core.cluster import consolidation_sweep, min_nodes_meeting_slo

res = consolidation_sweep(total_fns=800, node_counts=(14, 12, 10, 9),
                          duration_s=20.0)
for r in res:
    print(
        f"{r.policy:4s} nodes={r.n_nodes:2d}  p95={r.p95:7.3f}s  "
        f"util={r.util_effective*100:4.0f}%eff/{r.util_perceived*100:4.0f}%perc"
        f"  overhead={r.overhead_frac*100:4.1f}%"
    )
n_cfs = min_nodes_meeting_slo(res, "cfs")
n_lags = min_nodes_meeting_slo(res, "lags")
print(f"min nodes: CFS={n_cfs}  LAGS={n_lags} "
      f"({100*(1-n_lags/max(n_cfs,1)):.0f}% reduction)")
